//! Sharded worker-pool serving layer: N coordinator shards behind one
//! router.
//!
//! A single [`Coordinator`] loop thread serialises every model
//! evaluation, so one engine pipeline caps throughput no matter how many
//! cores or engine replicas exist. The [`WorkerPool`] scales out by
//! running N shards — each a full coordinator (own loop thread,
//! [`CoordinatorConfig`], and [`ModelBank`] handle: one shared
//! `Arc<dyn ModelBank>` or per-shard replicas) — fronted by:
//!
//! * a **router** with pluggable [`PlacementPolicy`]s ([`placement`]):
//!   round-robin, least-loaded by in-flight rows, and dataset-affinity
//!   hashing (per-dataset slabs stay dense because cross-request fusion
//!   only happens within a shard);
//! * **global admission control**: a cap on total in-flight rows across
//!   shards, surfaced to clients as the same
//!   [`SubmitError::QueueFull`] backpressure the shard queues use, plus
//!   queue-full failover from the preferred shard to its neighbours;
//! * **deadlines and cancellation**: every submit carries a
//!   [`CancelHandle`] and optional deadline that propagate into the
//!   shard loop, which retires the solver mid-trajectory (partial
//!   iterate, NFE consumed < budget) without poisoning batch-mates; a
//!   tag registry lets one connection cancel another connection's
//!   in-flight request over the wire;
//! * an aggregated [`PoolStats`] snapshot ([`stats`]) merging per-shard
//!   [`crate::coordinator::Telemetry`] (including executor utilisation
//!   and pipeline-depth histograms).
//!
//! Each shard is itself pipelined: a scheduler thread plus
//! `executors_per_shard` engine executors fed from a [`BankSet`] of
//! replicas, with up to `pipeline_depth` dispatch rounds in flight
//! (see [`crate::coordinator::service`]). `start_with_bank_sets` wires
//! per-shard replica sets; `start_with_banks` remains the one-bank-
//! per-shard special case.
//!
//! The TCP server ([`crate::server`]) serves from a pool; a pool with
//! one shard behaves exactly like the bare coordinator it wraps.

pub mod placement;
pub mod stats;

pub use placement::PlacementPolicy;
pub use stats::{PoolStats, ShardStats};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::service::Ticket;
use crate::coordinator::{
    BankSet, CancelHandle, CompletionNotify, ConnCounters, ConnSnapshot, Coordinator,
    CoordinatorConfig, ModelBank, RequestSpec, SamplingResult, SubmitError,
};
use crate::kernels::PlanCache;
use crate::obs::SpanEvent;

/// Pool construction knobs.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of coordinator shards (>= 1).
    pub shards: usize,
    pub placement: PlacementPolicy,
    /// Per-shard coordinator configuration (queue bound, batch policy,
    /// default deadline).
    pub shard: CoordinatorConfig,
    /// Global cap on in-flight rows across all shards; submits beyond
    /// it are rejected with [`SubmitError::QueueFull`]. 0 = unbounded.
    pub max_inflight_rows: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: 1,
            placement: PlacementPolicy::LeastLoaded,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 0,
        }
    }
}

/// A running pool of coordinator shards.
pub struct WorkerPool {
    shards: Vec<Coordinator>,
    placement: PlacementPolicy,
    /// Per-shard pipeline shape, surfaced in [`PoolStats`].
    executors_per_shard: usize,
    pipeline_depth: usize,
    /// Trajectory plans shared by every shard: one plan build per
    /// `(solver, nfe, grid, t_end, schedule)` across the whole pool.
    plans: Arc<PlanCache>,
    max_inflight_rows: usize,
    rr: AtomicUsize,
    pool_rejected: AtomicUsize,
    /// Serialises the global-cap check against the shard-side gauge
    /// increments: held across check + shard submit so two concurrent
    /// submits cannot both read a stale load sum and overshoot the cap.
    /// Only taken when `max_inflight_rows > 0`.
    admission: Mutex<()>,
    /// Wire-level cancellation registry: client-chosen tag -> cancel
    /// handle of the in-flight request carrying it.
    tags: Mutex<HashMap<u64, CancelHandle>>,
    /// Trace routing: client-chosen tag -> `(shard, request id)` of the
    /// flight-recorder trace it landed as. Unlike `tags`, entries
    /// survive completion (a finished or cancelled request stays
    /// traceable) and are evicted FIFO past [`TRACE_ROUTES_CAP`].
    traces: Mutex<TraceRoutes>,
    /// Connection counters of every front end serving from this pool
    /// (blocking server, gateway, or several of each); merged into one
    /// [`ConnSnapshot`] in [`PoolStats`].
    conns: Mutex<Vec<Arc<ConnCounters>>>,
}

/// Cap on remembered tag -> trace routes; the oldest route is evicted
/// first. Sized to comfortably outlive the shards' flight-recorder
/// rings, which overwrite event history long before 1024 requests.
const TRACE_ROUTES_CAP: usize = 1024;

/// FIFO-bounded tag -> `(shard, request id)` map. A tag re-used for a
/// newer request simply overwrites the route (latest wins); the FIFO
/// then tracks the tag's *first* insertion, so a heavily re-used tag
/// can be evicted earlier than its last use — acceptable for a
/// debugging facility.
#[derive(Default)]
struct TraceRoutes {
    map: HashMap<u64, (usize, u64)>,
    fifo: VecDeque<u64>,
}

impl TraceRoutes {
    fn insert(&mut self, tag: u64, shard: usize, id: u64) {
        if self.map.insert(tag, (shard, id)).is_none() {
            self.fifo.push_back(tag);
            while self.fifo.len() > TRACE_ROUTES_CAP {
                let Some(old) = self.fifo.pop_front() else { break };
                self.map.remove(&old);
            }
        }
    }
}

/// A pending pool response: the shard ticket plus where it was placed.
pub struct PoolTicket {
    /// Shard index the request was routed to.
    pub shard: usize,
    inner: Ticket,
}

impl PoolTicket {
    /// Shard-local request id (unique within `shard`, not pool-wide).
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// Block until the request finishes (or is retired by
    /// cancellation/deadline, yielding a `cancelled` result).
    pub fn wait(self) -> Result<SamplingResult, String> {
        self.inner.wait()
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<Result<SamplingResult, String>> {
        self.inner.wait_timeout(d)
    }

    /// Non-blocking poll; guaranteed `Some` once the submit's
    /// [`CompletionNotify`] has fired (see [`Ticket::try_result`]).
    pub fn try_result(&self) -> Option<Result<SamplingResult, String>> {
        self.inner.try_result()
    }

    /// Ask the owning shard to retire this request at its next round.
    pub fn cancel(&self) {
        self.inner.cancel();
    }

    pub fn cancel_handle(&self) -> CancelHandle {
        self.inner.cancel_handle()
    }
}

impl WorkerPool {
    /// Start `config.shards` shards over one shared model bank (the
    /// common case: [`crate::runtime::PjRtEngine`] serialises internally,
    /// `MockBank` is stateless).
    pub fn start(bank: Arc<dyn ModelBank>, config: PoolConfig) -> WorkerPool {
        assert!(config.shards >= 1, "pool needs at least one shard");
        let banks = (0..config.shards).map(|_| bank.clone()).collect();
        WorkerPool::start_with_banks(banks, config)
    }

    /// Start one shard per bank (per-shard engine replicas). The
    /// `config.shards` field is ignored in favour of `banks.len()`.
    /// Each shard's executors share that shard's bank handle; use
    /// [`WorkerPool::start_with_bank_sets`] for replicas *within* a
    /// shard.
    pub fn start_with_banks(banks: Vec<Arc<dyn ModelBank>>, config: PoolConfig) -> WorkerPool {
        assert!(!banks.is_empty(), "pool needs at least one bank");
        WorkerPool::start_with_bank_sets(
            banks.into_iter().map(BankSet::shared).collect(),
            config,
        )
    }

    /// Start one shard per [`BankSet`] — the fully general topology:
    /// N shards, each with its own set of engine replicas handed to
    /// that shard's `executors_per_shard` executor threads.
    pub fn start_with_bank_sets(sets: Vec<BankSet>, config: PoolConfig) -> WorkerPool {
        assert!(!sets.is_empty(), "pool needs at least one bank set");
        let plans = Arc::new(PlanCache::new());
        let shards = sets
            .into_iter()
            .map(|set| {
                Coordinator::start_with_bank_set(set, config.shard.clone(), plans.clone())
            })
            .collect();
        WorkerPool {
            shards,
            placement: config.placement,
            executors_per_shard: config.shard.executors_per_shard.max(1),
            pipeline_depth: config.shard.pipeline_depth.max(1),
            plans,
            max_inflight_rows: config.max_inflight_rows,
            rr: AtomicUsize::new(0),
            pool_rejected: AtomicUsize::new(0),
            admission: Mutex::new(()),
            tags: Mutex::new(HashMap::new()),
            traces: Mutex::new(TraceRoutes::default()),
            conns: Mutex::new(Vec::new()),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The pool-wide trajectory-plan cache every shard admits with.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    pub fn placement(&self) -> PlacementPolicy {
        self.placement
    }

    /// Per-shard in-flight row gauges (the router's load view).
    fn loads(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|c| c.telemetry().inflight_rows.load(Ordering::Relaxed))
            .collect()
    }

    /// Route and enqueue one request.
    pub fn submit(&self, spec: RequestSpec) -> Result<PoolTicket, SubmitError> {
        self.submit_tagged(spec, None)
    }

    /// Route and enqueue, optionally registering a client-chosen `tag`
    /// under which [`WorkerPool::cancel_tag`] (and the server's `cancel`
    /// op) can reach this request from another connection. A re-used
    /// tag displaces the previous registration.
    pub fn submit_tagged(
        &self,
        spec: RequestSpec,
        tag: Option<u64>,
    ) -> Result<PoolTicket, SubmitError> {
        self.submit_tagged_notify(spec, tag, None)
    }

    /// Like [`WorkerPool::submit_tagged`] with a completion callback:
    /// `notify` runs on the owning shard's loop thread right after the
    /// result lands in the ticket, making [`PoolTicket::try_result`]
    /// reliable for event-loop callers (the readiness gateway) without
    /// a parked thread per request.
    pub fn submit_tagged_notify(
        &self,
        spec: RequestSpec,
        tag: Option<u64>,
        notify: Option<CompletionNotify>,
    ) -> Result<PoolTicket, SubmitError> {
        // Register the cancel handle under the tag *before* any shard
        // can admit the request, so a concurrent `cancel` that observes
        // the request in flight always finds the tag. Cancels landing
        // in the pre-enqueue window simply make the envelope dead on
        // arrival.
        let cancel = CancelHandle::new();
        if let Some(tag) = tag {
            self.tags.lock().unwrap().insert(tag, cancel.clone());
        }
        let result = self.route_and_submit(&spec, &cancel, notify);
        match (&result, tag) {
            // Remember where the tagged request landed so `trace <tag>`
            // can replay its flight-recorder spans — including after it
            // completes or is cancelled.
            (Ok(ticket), Some(tag)) => {
                self.traces.lock().unwrap().insert(tag, ticket.shard, ticket.id());
            }
            (Err(_), Some(tag)) => self.deregister_tag(tag, &cancel),
            _ => {}
        }
        result
    }

    /// Resolve a client tag to the `(shard, request id)` its request
    /// landed as. The request id is the shard-local trace id.
    pub fn trace_route(&self, tag: u64) -> Option<(usize, u64)> {
        self.traces.lock().unwrap().map.get(&tag).copied()
    }

    /// Replay the flight-recorder span events (oldest -> newest) of the
    /// request submitted under `tag`: `(shard, trace id, events)`.
    /// `None` when the tag was never registered or its route was
    /// evicted; an empty event list when the shard's ring has since
    /// overwritten the request's history.
    pub fn trace_events(&self, tag: u64) -> Option<(usize, u64, Vec<SpanEvent>)> {
        let (shard, id) = self.trace_route(tag)?;
        Some((shard, id, self.shards[shard].recorder().snapshot_trace(id)))
    }

    fn route_and_submit(
        &self,
        spec: &RequestSpec,
        cancel: &CancelHandle,
        notify: Option<CompletionNotify>,
    ) -> Result<PoolTicket, SubmitError> {
        let mut spec = spec.clone();
        // Under a global cap, hold the admission lock across the
        // check *and* the shard submit (which bumps the inflight
        // gauges synchronously) — otherwise two concurrent submits
        // could both pass a stale check and overshoot the cap.
        let _admission_guard = if self.max_inflight_rows > 0 {
            let guard = self.admission.lock().unwrap();
            let total: usize = self.loads().iter().sum();
            // Admission is charged in model-eval rows: a guided request
            // costs paired cond/uncond rows, i.e. 2x its sample count
            // (`RequestSpec::admission_rows`), matching the shard-side
            // inflight_rows gauge this cap is compared against.
            // Adaptive QoS tiers are charged their *predicted* rows
            // (`RequestSpec::charged_rows`): the NFE floor for
            // besteffort, the floor/budget midpoint for balanced with
            // the controller on — strict always pays worst case.
            if total + spec.charged_rows() > self.max_inflight_rows {
                self.pool_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::QueueFull);
            }
            // Squeezed in past the worst-case cap on the strength of a
            // degradable floor charge: latch the request degraded so
            // it actually delivers the floor it was charged for,
            // instead of rejecting it like a strict request.
            if total + spec.admission_rows() > self.max_inflight_rows && spec.degradable() {
                spec.degraded = true;
            }
            Some(guard)
        } else {
            None
        };
        let loads = self.loads();
        let n = self.shards.len();
        let rr = self.rr.fetch_add(1, Ordering::Relaxed);
        let first = placement::place(self.placement, &spec.dataset, rr, &loads);
        for k in 0..n {
            let idx = (first + k) % n;
            match self.shards[idx].submit_with_cancel_notify(
                spec.clone(),
                cancel.clone(),
                notify.clone(),
            ) {
                Ok(ticket) => return Ok(PoolTicket { shard: idx, inner: ticket }),
                // Queue-full fails over to the next shard; anything else
                // (invalid spec, shutdown) is terminal.
                Err(SubmitError::QueueFull) => continue,
                Err(e) => return Err(e),
            }
        }
        self.pool_rejected.fetch_add(1, Ordering::Relaxed);
        Err(SubmitError::QueueFull)
    }

    /// Cancel the in-flight request registered under `tag`. Returns
    /// false when no such tag is live.
    pub fn cancel_tag(&self, tag: u64) -> bool {
        match self.tags.lock().unwrap().remove(&tag) {
            Some(handle) => {
                handle.cancel();
                true
            }
            None => false,
        }
    }

    /// Drop a tag registration without cancelling (called after the
    /// tagged request completes). Identity-checked: only removes the
    /// entry if it still belongs to `handle`'s request, so a tag that
    /// was re-used by a newer request is left alone.
    pub fn deregister_tag(&self, tag: u64, handle: &CancelHandle) {
        let mut tags = self.tags.lock().unwrap();
        if tags.get(&tag).is_some_and(|h| h.same_as(handle)) {
            tags.remove(&tag);
        }
    }

    /// Convenience: submit and wait.
    pub fn sample(&self, spec: RequestSpec) -> Result<SamplingResult, String> {
        self.submit(spec).map_err(|e| format!("{e:?}"))?.wait()
    }

    /// Advisory accept-throttle hook for front ends: false when the
    /// global in-flight row cap is already met, i.e. the next sample of
    /// any size would be rejected at admission. Front ends use it to
    /// pause `accept()` (leaving new connections in the kernel backlog)
    /// instead of accepting work they would immediately shed. Always
    /// true when the pool is uncapped. Advisory only: the admission
    /// lock in [`WorkerPool::submit_tagged`] remains the authority.
    pub fn has_admission_capacity(&self) -> bool {
        if self.max_inflight_rows == 0 {
            return true;
        }
        let total: usize = self.loads().iter().sum();
        total < self.max_inflight_rows
    }

    /// Register a front end's connection counters; its snapshot merges
    /// into every subsequent [`WorkerPool::stats`] call.
    pub fn register_conn_counters(&self, counters: Arc<ConnCounters>) {
        self.conns.lock().unwrap().push(counters);
    }

    /// Merged connection snapshot across every registered front end.
    pub fn conn_snapshot(&self) -> ConnSnapshot {
        let mut merged = ConnSnapshot::default();
        for c in self.conns.lock().unwrap().iter() {
            merged.merge(&c.snapshot());
        }
        merged
    }

    /// Merged snapshot across shards.
    pub fn stats(&self) -> PoolStats {
        let teles: Vec<&crate::coordinator::Telemetry> =
            self.shards.iter().map(|c| c.telemetry()).collect();
        PoolStats::collect_with_conns(
            self.placement.label(),
            &teles,
            self.pool_rejected.load(Ordering::Relaxed),
            self.executors_per_shard,
            self.pipeline_depth,
            self.conn_snapshot(),
        )
    }

    /// Stop accepting work, drain every shard, join the loop threads.
    pub fn shutdown(self) {
        for shard in self.shards {
            shard.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockBank;
    use crate::solvers::eps_model::AnalyticGmm;
    use crate::solvers::schedule::VpSchedule;

    fn bank() -> Arc<dyn ModelBank> {
        let sched = VpSchedule::default();
        Arc::new(
            MockBank::new(sched)
                .with("gmm8", Box::new(AnalyticGmm::gmm8(sched)))
                .with("gmm8b", Box::new(AnalyticGmm::gmm8(sched))),
        )
    }

    fn spec(n: usize, seed: u64) -> RequestSpec {
        RequestSpec { n_samples: n, seed, ..Default::default() }
    }

    fn pool(shards: usize, placement: PlacementPolicy) -> WorkerPool {
        WorkerPool::start(bank(), PoolConfig { shards, placement, ..Default::default() })
    }

    #[test]
    fn single_shard_pool_matches_bare_coordinator() {
        // The pool path must be numerically identical to the in-process
        // solver drive (same seed, same model) — same invariant the
        // coordinator keeps.
        let sched = VpSchedule::default();
        let p = pool(1, PlacementPolicy::RoundRobin);
        let s = spec(64, 9);
        let via_pool = p.sample(s.clone()).unwrap();
        p.shutdown();

        let model = AnalyticGmm::gmm8(sched);
        let mut solver = s.build_solver(sched, 2).unwrap();
        let direct = crate::solvers::sample_with(&mut *solver, &model);
        assert_eq!(via_pool.samples.as_slice(), direct.as_slice());
        assert!(!via_pool.cancelled);
    }

    #[test]
    fn round_robin_spreads_sequential_requests() {
        let p = pool(2, PlacementPolicy::RoundRobin);
        for i in 0..4 {
            p.sample(spec(8, i)).unwrap();
        }
        let stats = p.stats();
        assert_eq!(stats.per_shard[0].admitted, 2);
        assert_eq!(stats.per_shard[1].admitted, 2);
        assert_eq!(stats.finished(), 4);
        p.shutdown();
    }

    #[test]
    fn least_loaded_ties_break_to_first_shard() {
        // Sequential requests always see idle shards, so the tie-break
        // must deterministically pick shard 0 every time.
        let p = pool(3, PlacementPolicy::LeastLoaded);
        for i in 0..3 {
            p.sample(spec(8, i)).unwrap();
        }
        let stats = p.stats();
        assert_eq!(stats.per_shard[0].admitted, 3);
        assert_eq!(stats.per_shard[1].admitted, 0);
        assert_eq!(stats.per_shard[2].admitted, 0);
        p.shutdown();
    }

    #[test]
    fn affinity_pins_a_dataset_to_one_shard() {
        let p = pool(4, PlacementPolicy::DatasetAffinity);
        for i in 0..6 {
            p.sample(spec(8, i)).unwrap();
        }
        let stats = p.stats();
        let hot: Vec<&ShardStats> =
            stats.per_shard.iter().filter(|s| s.admitted > 0).collect();
        assert_eq!(hot.len(), 1, "one dataset must land on exactly one shard");
        assert_eq!(hot[0].admitted, 6);
        p.shutdown();
    }

    #[test]
    fn invalid_spec_is_not_failed_over() {
        let p = pool(2, PlacementPolicy::RoundRobin);
        let mut s = spec(4, 0);
        s.solver = "frobnicate".into();
        match p.submit(s) {
            Err(SubmitError::Invalid(_)) => {}
            other => panic!("expected Invalid, got {:?}", other.map(|t| t.shard)),
        }
        assert_eq!(p.stats().pool_rejected, 0);
        p.shutdown();
    }

    #[test]
    fn tag_registry_cancels_and_clears() {
        let p = pool(1, PlacementPolicy::RoundRobin);
        let t = p.submit_tagged(spec(8, 0), Some(42)).unwrap();
        let handle = t.cancel_handle();
        // Whatever the request's fate, the tag must be cancellable once
        // and gone after.
        assert!(p.cancel_tag(42));
        assert!(!p.cancel_tag(42));
        let _ = t.wait();
        p.deregister_tag(42, &handle); // idempotent on a cleared tag
        p.shutdown();
    }

    #[test]
    fn reused_tag_is_not_evicted_by_stale_deregister() {
        let p = pool(1, PlacementPolicy::RoundRobin);
        let old = p.submit_tagged(spec(8, 0), Some(7)).unwrap();
        let old_handle = old.cancel_handle();
        let _ = old.wait();
        // A newer request re-uses the tag before the old one's handler
        // deregisters; the stale deregister must leave it alone.
        let newer = p.submit_tagged(spec(8, 1), Some(7)).unwrap();
        p.deregister_tag(7, &old_handle);
        assert!(p.cancel_tag(7), "re-used tag must survive a stale deregister");
        let _ = newer.wait();
        p.shutdown();
    }

    #[test]
    fn failed_submit_does_not_leak_tag() {
        let p = pool(1, PlacementPolicy::RoundRobin);
        let mut s = spec(4, 0);
        s.solver = "frobnicate".into();
        assert!(p.submit_tagged(s, Some(9)).is_err());
        assert!(!p.cancel_tag(9), "tag from a failed submit must be cleaned up");
        p.shutdown();
    }

    #[test]
    fn shards_share_one_plan_cache() {
        // Round-robin over 2 shards: both shards admit the same spec
        // shape, yet the configuration is planned exactly once pool-wide.
        let p = pool(2, PlacementPolicy::RoundRobin);
        for i in 0..4 {
            p.sample(spec(8, i)).unwrap();
        }
        let stats = p.stats();
        assert!(stats.per_shard.iter().all(|s| s.admitted == 2), "requests must spread");
        assert_eq!(p.plan_cache().misses(), 1, "one plan build across shards");
        assert_eq!(p.plan_cache().hits(), 3);
        p.shutdown();
    }

    #[test]
    fn pipelined_shards_match_serialized_pool_bitwise() {
        // Same seeds through a depth-1/1-executor pool and a
        // depth-3/2-executor pool over per-shard BankSet replicas:
        // every sample must be bit-identical.
        let run = |executors: usize, depth: usize| -> Vec<Vec<f32>> {
            let shard = CoordinatorConfig {
                executors_per_shard: executors,
                pipeline_depth: depth,
                ..Default::default()
            };
            let sets = vec![
                BankSet::new(vec![bank(), bank()]),
                BankSet::new(vec![bank(), bank()]),
            ];
            let p = WorkerPool::start_with_bank_sets(
                sets,
                PoolConfig {
                    shards: 2,
                    placement: PlacementPolicy::RoundRobin,
                    shard,
                    max_inflight_rows: 0,
                },
            );
            let tickets: Vec<_> = (0..6).map(|i| p.submit(spec(16, i)).unwrap()).collect();
            let out = tickets
                .into_iter()
                .map(|t| t.wait().unwrap().samples.as_slice().to_vec())
                .collect();
            p.shutdown();
            out
        };
        assert_eq!(run(2, 3), run(1, 1));
    }

    #[test]
    fn pool_stats_carry_pipeline_shape() {
        let shard = CoordinatorConfig {
            executors_per_shard: 2,
            pipeline_depth: 2,
            ..Default::default()
        };
        let p = WorkerPool::start(
            bank(),
            PoolConfig { shards: 2, shard, ..Default::default() },
        );
        for i in 0..4 {
            p.sample(spec(8, i)).unwrap();
        }
        let s = p.stats();
        assert_eq!(s.executors_per_shard, 2);
        assert_eq!(s.pipeline_depth, 2);
        assert!(s.executor_busy_fraction() > 0.0, "executors never clocked busy time");
        assert_eq!(s.inflight_slabs(), 0, "slab gauge must drain");
        assert!(s.depth_hist().iter().sum::<usize>() > 0, "no dispatches recorded");
        p.shutdown();
    }

    #[test]
    fn pool_stats_merge_across_shards() {
        let p = pool(2, PlacementPolicy::RoundRobin);
        for i in 0..4 {
            p.sample(spec(16, i)).unwrap();
        }
        let s = p.stats();
        assert_eq!(s.shards(), 2);
        assert_eq!(s.finished(), 4);
        assert_eq!(s.admitted(), 4);
        assert!(s.evals() >= 20, "evals {}", s.evals());
        assert_eq!(s.inflight_rows(), 0);
        assert!(s.summary().contains("placement=round-robin"));
        p.shutdown();
    }

    #[test]
    fn trace_events_resolve_by_tag_across_shards() {
        use crate::obs::SpanKind;
        let p = pool(2, PlacementPolicy::RoundRobin);
        let t1 = p.submit_tagged(spec(8, 0), Some(100)).unwrap();
        let t2 = p.submit_tagged(spec(8, 1), Some(101)).unwrap();
        let (s1, s2) = (t1.shard, t2.shard);
        t1.wait().unwrap();
        t2.wait().unwrap();
        let (shard, _, events) = p.trace_events(100).expect("tag 100 routed");
        assert_eq!(shard, s1);
        assert!(matches!(events.first().map(|e| e.kind), Some(SpanKind::Admitted { .. })));
        assert!(
            matches!(events.last().map(|e| e.kind), Some(SpanKind::Finalize { .. })),
            "completed request stays traceable: {events:?}"
        );
        let (shard2, _, ev2) = p.trace_events(101).expect("tag 101 routed");
        assert_eq!(shard2, s2);
        assert!(!ev2.is_empty());
        assert!(p.trace_events(999).is_none(), "unknown tag has no route");
        p.shutdown();
    }

    #[test]
    fn shutdown_drains_every_shard() {
        let p = pool(2, PlacementPolicy::RoundRobin);
        let tickets: Vec<_> = (0..4).map(|i| p.submit(spec(16, i)).unwrap()).collect();
        p.shutdown();
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn completion_notify_makes_try_result_reliable() {
        // The gateway's contract: once the notify callback fires, the
        // ticket polls `Some` without blocking — the loop sends the
        // reply before notifying.
        let p = pool(1, PlacementPolicy::RoundRobin);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let notify: CompletionNotify = Arc::new(move || {
            let _ = tx.send(());
        });
        let t = p.submit_tagged_notify(spec(8, 3), None, Some(notify)).unwrap();
        rx.recv_timeout(Duration::from_secs(10)).expect("notify must fire");
        let out = t.try_result().expect("result must be present after notify");
        assert_eq!(out.unwrap().samples.rows(), 8);
        assert!(t.try_result().is_none(), "a result is delivered exactly once");
        p.shutdown();
    }

    #[test]
    fn admission_capacity_tracks_inflight_rows() {
        // Uncapped pools always report capacity; capped pools report
        // none once the in-flight rows meet the cap, and recover after
        // the work drains.
        let p = pool(1, PlacementPolicy::RoundRobin);
        assert!(p.has_admission_capacity());
        p.shutdown();

        let capped = WorkerPool::start(
            bank(),
            PoolConfig {
                shards: 1,
                placement: PlacementPolicy::RoundRobin,
                shard: CoordinatorConfig::default(),
                max_inflight_rows: 8,
            },
        );
        assert!(capped.has_admission_capacity());
        let t = capped.submit(spec(8, 0)).unwrap();
        // 8 rows in flight == cap: no headroom for any further request.
        assert!(!capped.has_admission_capacity());
        t.wait().unwrap();
        assert!(capped.has_admission_capacity(), "capacity must recover after drain");
        capped.shutdown();
    }

    #[test]
    fn conn_counters_from_multiple_front_ends_merge_into_stats() {
        let p = pool(1, PlacementPolicy::RoundRobin);
        let a = Arc::new(ConnCounters::new());
        let b = Arc::new(ConnCounters::new());
        p.register_conn_counters(a.clone());
        p.register_conn_counters(b.clone());
        a.open_connections.store(2, Ordering::Relaxed);
        a.accepted_total.store(5, Ordering::Relaxed);
        b.open_connections.store(1, Ordering::Relaxed);
        b.accepted_total.store(3, Ordering::Relaxed);
        b.rejected_total.store(1, Ordering::Relaxed);
        b.backpressure_stalls.store(4, Ordering::Relaxed);
        let s = p.stats();
        assert_eq!(s.conn.open_connections, 3);
        assert_eq!(s.conn.accepted_total, 8);
        assert_eq!(s.conn.rejected_total, 1);
        assert_eq!(s.conn.backpressure_stalls, 4);
        p.shutdown();
    }
}
