//! Placement: which shard gets the next request.
//!
//! The router is a pure function of (policy, dataset, round-robin
//! counter, per-shard loads) so every policy is unit-testable without
//! threads. Loads are the shards' `inflight_rows` telemetry gauges —
//! rows submitted but not yet retired — which makes least-loaded
//! placement track the actual row mass each shard is carrying rather
//! than a request count that ignores batch size. The gauge is charged
//! in *model-eval rows* (`RequestSpec::admission_rows`), so a guided
//! request's paired cond/uncond rows weigh double and the router sees
//! the true per-shard evaluation load under mixed workloads.

/// How the pool routes requests across shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Cycle through shards; ignores load and dataset.
    RoundRobin,
    /// Shard with the fewest in-flight rows (ties -> lowest index).
    LeastLoaded,
    /// Hash the dataset name to a shard so each dataset's evaluations
    /// concentrate on one shard and its slabs stay dense (cross-request
    /// fusion only happens within a shard).
    DatasetAffinity,
}

impl PlacementPolicy {
    /// Parse CLI / protocol names.
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            "least-loaded" | "ll" => Some(PlacementPolicy::LeastLoaded),
            "affinity" | "dataset-affinity" => Some(PlacementPolicy::DatasetAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::DatasetAffinity => "affinity",
        }
    }
}

/// FNV-1a 64-bit (stable across runs, unlike `DefaultHasher`).
pub(crate) fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Pick the preferred shard for one request. `loads[i]` is shard i's
/// in-flight row gauge; `rr_counter` is a monotonically increasing
/// submit counter. The caller may still fail over to other shards when
/// the preferred one's admission queue is full.
pub fn place(policy: PlacementPolicy, dataset: &str, rr_counter: usize, loads: &[usize]) -> usize {
    let n = loads.len();
    debug_assert!(n > 0, "place over zero shards");
    match policy {
        PlacementPolicy::RoundRobin => rr_counter % n,
        PlacementPolicy::LeastLoaded => loads
            .iter()
            .enumerate()
            .min_by_key(|&(i, &l)| (l, i))
            .map(|(i, _)| i)
            .unwrap_or(0),
        PlacementPolicy::DatasetAffinity => (fnv1a(dataset) % n as u64) as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for p in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::DatasetAffinity,
        ] {
            assert_eq!(PlacementPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("rr"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("ll"), Some(PlacementPolicy::LeastLoaded));
        assert_eq!(
            PlacementPolicy::parse("dataset-affinity"),
            Some(PlacementPolicy::DatasetAffinity)
        );
        assert_eq!(PlacementPolicy::parse("banana"), None);
    }

    #[test]
    fn round_robin_cycles() {
        let loads = [0usize; 3];
        let picks: Vec<usize> =
            (0..6).map(|c| place(PlacementPolicy::RoundRobin, "gmm8", c, &loads)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_minimum_with_stable_ties() {
        assert_eq!(place(PlacementPolicy::LeastLoaded, "x", 0, &[5, 2, 9, 2]), 1);
        assert_eq!(place(PlacementPolicy::LeastLoaded, "x", 0, &[0, 0, 0]), 0);
        assert_eq!(place(PlacementPolicy::LeastLoaded, "x", 7, &[3]), 0);
        // A guided request's paired rows weigh double in the gauge: a
        // shard holding one guided 16-sample request (32 rows) loses to
        // one holding a plain 16-row request.
        assert_eq!(place(PlacementPolicy::LeastLoaded, "x", 0, &[32, 16]), 1);
    }

    #[test]
    fn affinity_is_deterministic_and_in_range() {
        for n in [1usize, 2, 3, 4, 7] {
            let loads = vec![0usize; n];
            for ds in ["gmm8", "checkerboard", "swissroll", "rings"] {
                let a = place(PlacementPolicy::DatasetAffinity, ds, 0, &loads);
                let b = place(PlacementPolicy::DatasetAffinity, ds, 99, &loads);
                assert_eq!(a, b, "affinity must ignore the rr counter");
                assert!(a < n);
            }
        }
        // The standard two-dataset pair used in tests should spread over
        // enough shards (pinning both to one shard would make the policy
        // useless in the common case); fnv1a separates them at n=2.
        let l2 = [0usize, 0];
        let a = place(PlacementPolicy::DatasetAffinity, "gmm8", 0, &l2);
        let b = place(PlacementPolicy::DatasetAffinity, "gmm8b", 0, &l2);
        assert!(a < 2 && b < 2);
    }

    #[test]
    fn fnv1a_differs_across_names() {
        assert_ne!(fnv1a("gmm8"), fnv1a("checkerboard"));
        assert_eq!(fnv1a("gmm8"), fnv1a("gmm8"));
    }
}
