//! Aggregated pool statistics: one merged view over N shard
//! [`Telemetry`] instances.
//!
//! Counters add; occupancy/padding re-derive from the summed rows and
//! evals; percentiles are computed over the *pooled* raw latency
//! samples (averaging per-shard percentiles would be wrong whenever
//! shards carry uneven load).

use std::sync::atomic::Ordering;

use crate::coordinator::telemetry::{
    fmt_quantile_ms, sorted_percentile, StageHistSnapshot, DEPTH_HIST_BUCKETS, LANE_OCC_BUCKETS,
    NFE_HIST_BOUNDS, NFE_HIST_BUCKETS, STAGES, STAGE_BOUNDS,
};
use crate::coordinator::{ConnSnapshot, Telemetry};
use crate::json::Json;
use crate::obs::PromText;

/// One shard's counters at snapshot time.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub shard: usize,
    pub admitted: usize,
    pub finished: usize,
    pub cancelled: usize,
    pub rejected: usize,
    pub evals: usize,
    pub rows: usize,
    pub padded_rows: usize,
    pub inflight_requests: usize,
    pub inflight_rows: usize,
    /// Workload mix (see [`Telemetry`]): guided / img2img / stochastic
    /// requests admitted on this shard.
    pub guided: usize,
    pub img2img: usize,
    pub stochastic: usize,
    /// Executor-thread utilisation clocks (summed across the shard's
    /// executors) and the in-flight slab gauge.
    pub executor_busy_nanos: u64,
    pub executor_idle_nanos: u64,
    pub inflight_slabs: usize,
    /// Bytes that crossed the host↔engine boundary on this shard
    /// (slab payloads + outputs, resident uploads/ops/gathers).
    pub host_bytes_transferred: u64,
    /// Gauge: lanes currently stepping engine-resident on this shard.
    pub resident_lanes: usize,
    /// Pipeline-depth histogram: `depth_hist[d-1]` dispatches happened
    /// at `d` rounds in flight (last bucket absorbs deeper).
    pub depth_hist: [usize; DEPTH_HIST_BUCKETS],
    /// Live-lane gauge of the shard's lane engine at the last dispatch.
    pub lanes: usize,
    /// Lane-occupancy histogram: `lane_occ_hist[m-1]` lane dispatches
    /// carried `m` fused member requests (last bucket absorbs deeper).
    pub lane_occ_hist: [usize; LANE_OCC_BUCKETS],
    /// Sum / count of final per-request `delta_eps` values (ERA only).
    pub delta_eps_sum: f64,
    pub delta_eps_count: usize,
    /// Requests retired early by the convergence controller.
    pub early_stops: usize,
    /// Requests latched to their NFE floor (cap squeeze-in or deadline
    /// pressure on a best-effort request).
    pub degraded_requests: usize,
    /// Delivered-NFE histogram: bucket upper bounds are
    /// [`NFE_HIST_BOUNDS`], last bucket absorbs larger.
    pub delivered_nfe_hist: [u64; NFE_HIST_BUCKETS],
    /// Per-stage latency histogram snapshots, in [`STAGES`] order
    /// (queue, solver_step, eval, finalize).
    pub stages: [StageHistSnapshot; 4],
}

impl ShardStats {
    pub fn from_telemetry(shard: usize, t: &Telemetry) -> ShardStats {
        // One locked read: two separate agg() calls could tear the
        // (sum, count) pair against a concurrent record_delta_eps.
        let (delta_eps_sum, delta_eps_count) = t.delta_eps_agg();
        ShardStats {
            shard,
            admitted: t.requests_admitted.load(Ordering::Relaxed),
            finished: t.requests_finished.load(Ordering::Relaxed),
            cancelled: t.requests_cancelled.load(Ordering::Relaxed),
            rejected: t.requests_rejected.load(Ordering::Relaxed),
            evals: t.evals.load(Ordering::Relaxed),
            rows: t.rows.load(Ordering::Relaxed),
            padded_rows: t.padded_rows.load(Ordering::Relaxed),
            inflight_requests: t.inflight_requests.load(Ordering::Relaxed),
            inflight_rows: t.inflight_rows.load(Ordering::Relaxed),
            guided: t.guided_requests.load(Ordering::Relaxed),
            img2img: t.img2img_requests.load(Ordering::Relaxed),
            stochastic: t.stochastic_requests.load(Ordering::Relaxed),
            executor_busy_nanos: t.executor_busy_nanos.load(Ordering::Relaxed),
            executor_idle_nanos: t.executor_idle_nanos.load(Ordering::Relaxed),
            inflight_slabs: t.inflight_slabs.load(Ordering::Relaxed),
            host_bytes_transferred: t.host_bytes_transferred.load(Ordering::Relaxed),
            resident_lanes: t.resident_lanes.load(Ordering::Relaxed),
            depth_hist: t.depth_hist_snapshot(),
            lanes: t.lanes.load(Ordering::Relaxed),
            lane_occ_hist: t.lane_occ_snapshot(),
            delta_eps_sum,
            delta_eps_count,
            early_stops: t.early_stops.load(Ordering::Relaxed),
            degraded_requests: t.degraded_requests.load(Ordering::Relaxed),
            delivered_nfe_hist: t.nfe_hist_snapshot(),
            stages: t.stage_snapshots(),
        }
    }

    /// Mean final `delta_eps` over this shard's finished ERA requests.
    pub fn mean_delta_eps(&self) -> f64 {
        if self.delta_eps_count == 0 {
            0.0
        } else {
            self.delta_eps_sum / self.delta_eps_count as f64
        }
    }

    /// Mean rows per fused evaluation on this shard.
    pub fn occupancy(&self) -> f64 {
        if self.evals == 0 {
            0.0
        } else {
            self.rows as f64 / self.evals as f64
        }
    }

    /// Fraction of executor thread time spent evaluating on this shard.
    pub fn executor_busy_fraction(&self) -> f64 {
        let total = self.executor_busy_nanos + self.executor_idle_nanos;
        if total == 0 {
            0.0
        } else {
            self.executor_busy_nanos as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("finished", Json::Num(self.finished as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("evals", Json::Num(self.evals as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("inflight_requests", Json::Num(self.inflight_requests as f64)),
            ("inflight_rows", Json::Num(self.inflight_rows as f64)),
            ("occupancy", Json::Num(self.occupancy())),
            ("guided", Json::Num(self.guided as f64)),
            ("img2img", Json::Num(self.img2img as f64)),
            ("stochastic", Json::Num(self.stochastic as f64)),
            ("executor_busy_frac", Json::Num(self.executor_busy_fraction())),
            ("inflight_slabs", Json::Num(self.inflight_slabs as f64)),
            ("host_bytes_transferred", Json::Num(self.host_bytes_transferred as f64)),
            ("resident_lanes", Json::Num(self.resident_lanes as f64)),
            (
                "depth_hist",
                Json::Arr(self.depth_hist.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("lanes", Json::Num(self.lanes as f64)),
            (
                "lane_occ_hist",
                Json::Arr(self.lane_occ_hist.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("mean_delta_eps", Json::Num(self.mean_delta_eps())),
            ("early_stops", Json::Num(self.early_stops as f64)),
            ("degraded_requests", Json::Num(self.degraded_requests as f64)),
            (
                "delivered_nfe_hist",
                Json::Arr(self.delivered_nfe_hist.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            (
                "stages",
                Json::obj(
                    STAGES
                        .iter()
                        .zip(self.stages.iter())
                        .map(|(name, s)| (*name, s.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Merged snapshot over every shard of a pool.
#[derive(Clone, Debug)]
pub struct PoolStats {
    pub placement: &'static str,
    pub per_shard: Vec<ShardStats>,
    /// Requests the pool itself refused (global admission control or
    /// every shard's queue full) — shard-level queue rejections are in
    /// `per_shard[i].rejected`.
    pub pool_rejected: usize,
    /// Pipeline shape every shard runs with.
    pub executors_per_shard: usize,
    pub pipeline_depth: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Connection-level counters merged across every front end (legacy
    /// server and/or gateway) registered with the pool. All-zero when
    /// the pool is driven in-process with no server attached.
    pub conn: ConnSnapshot,
}

impl PoolStats {
    /// Snapshot and merge the given shards' telemetry.
    pub fn collect(
        placement: &'static str,
        telemetries: &[&Telemetry],
        pool_rejected: usize,
        executors_per_shard: usize,
        pipeline_depth: usize,
    ) -> PoolStats {
        PoolStats::collect_with_conns(
            placement,
            telemetries,
            pool_rejected,
            executors_per_shard,
            pipeline_depth,
            ConnSnapshot::default(),
        )
    }

    /// [`PoolStats::collect`] plus a pre-merged connection snapshot from
    /// the pool's registered front ends.
    pub fn collect_with_conns(
        placement: &'static str,
        telemetries: &[&Telemetry],
        pool_rejected: usize,
        executors_per_shard: usize,
        pipeline_depth: usize,
        conn: ConnSnapshot,
    ) -> PoolStats {
        let per_shard: Vec<ShardStats> = telemetries
            .iter()
            .enumerate()
            .map(|(i, t)| ShardStats::from_telemetry(i, t))
            .collect();
        let mut lat: Vec<f64> = Vec::new();
        for t in telemetries {
            lat.extend(t.latency_samples());
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        PoolStats {
            placement,
            per_shard,
            pool_rejected,
            executors_per_shard,
            pipeline_depth,
            p50_ms: 1e3 * sorted_percentile(&lat, 0.5),
            p99_ms: 1e3 * sorted_percentile(&lat, 0.99),
            conn,
        }
    }

    pub fn shards(&self) -> usize {
        self.per_shard.len()
    }

    pub fn admitted(&self) -> usize {
        self.per_shard.iter().map(|s| s.admitted).sum()
    }

    pub fn finished(&self) -> usize {
        self.per_shard.iter().map(|s| s.finished).sum()
    }

    pub fn cancelled(&self) -> usize {
        self.per_shard.iter().map(|s| s.cancelled).sum()
    }

    /// Shard queue rejections plus pool-level rejections.
    pub fn rejected(&self) -> usize {
        self.per_shard.iter().map(|s| s.rejected).sum::<usize>() + self.pool_rejected
    }

    pub fn evals(&self) -> usize {
        self.per_shard.iter().map(|s| s.evals).sum()
    }

    pub fn rows(&self) -> usize {
        self.per_shard.iter().map(|s| s.rows).sum()
    }

    pub fn inflight_rows(&self) -> usize {
        self.per_shard.iter().map(|s| s.inflight_rows).sum()
    }

    /// Slabs currently dispatched-but-unrouted across all shards.
    pub fn inflight_slabs(&self) -> usize {
        self.per_shard.iter().map(|s| s.inflight_slabs).sum()
    }

    /// Host↔engine bytes across all shards (counters sum).
    pub fn host_bytes_transferred(&self) -> u64 {
        self.per_shard.iter().map(|s| s.host_bytes_transferred).sum()
    }

    /// Engine-resident lanes across all shards (gauges sum).
    pub fn resident_lanes(&self) -> usize {
        self.per_shard.iter().map(|s| s.resident_lanes).sum()
    }

    /// Pool-wide executor utilisation: summed busy clocks over summed
    /// total clocks (a per-shard average would overweight idle shards).
    pub fn executor_busy_fraction(&self) -> f64 {
        let busy: u64 = self.per_shard.iter().map(|s| s.executor_busy_nanos).sum();
        let idle: u64 = self.per_shard.iter().map(|s| s.executor_idle_nanos).sum();
        if busy + idle == 0 {
            0.0
        } else {
            busy as f64 / (busy + idle) as f64
        }
    }

    /// Element-wise sum of the shards' pipeline-depth histograms.
    pub fn depth_hist(&self) -> [usize; DEPTH_HIST_BUCKETS] {
        let mut out = [0usize; DEPTH_HIST_BUCKETS];
        for s in &self.per_shard {
            for (o, n) in out.iter_mut().zip(s.depth_hist.iter()) {
                *o += n;
            }
        }
        out
    }

    /// Live lanes across all shards (gauges sum).
    pub fn lanes(&self) -> usize {
        self.per_shard.iter().map(|s| s.lanes).sum()
    }

    /// Requests retired early by the convergence controller, pool-wide.
    pub fn early_stops(&self) -> usize {
        self.per_shard.iter().map(|s| s.early_stops).sum()
    }

    /// Requests latched to their NFE floor, pool-wide.
    pub fn degraded_requests(&self) -> usize {
        self.per_shard.iter().map(|s| s.degraded_requests).sum()
    }

    /// Element-wise sum of the shards' delivered-NFE histograms.
    pub fn delivered_nfe_hist(&self) -> [u64; NFE_HIST_BUCKETS] {
        let mut out = [0u64; NFE_HIST_BUCKETS];
        for s in &self.per_shard {
            for (o, n) in out.iter_mut().zip(s.delivered_nfe_hist.iter()) {
                *o += n;
            }
        }
        out
    }

    /// Element-wise sum of the shards' lane-occupancy histograms.
    pub fn lane_occ_hist(&self) -> [usize; LANE_OCC_BUCKETS] {
        let mut out = [0usize; LANE_OCC_BUCKETS];
        for s in &self.per_shard {
            for (o, n) in out.iter_mut().zip(s.lane_occ_hist.iter()) {
                *o += n;
            }
        }
        out
    }

    /// Per-stage latency histograms pooled across shards (element-wise
    /// bucket sums), in [`STAGES`] order.
    pub fn stage_hists(&self) -> [StageHistSnapshot; 4] {
        let mut out = [StageHistSnapshot::default(); 4];
        for s in &self.per_shard {
            for (o, h) in out.iter_mut().zip(s.stages.iter()) {
                o.merge(h);
            }
        }
        out
    }

    /// Render the full merged snapshot in Prometheus text exposition
    /// format (0.0.4): every counter/gauge, the pipeline-depth and
    /// lane-occupancy distributions (labelled counters), and the
    /// per-stage latency histograms as conventional `_bucket`/`_sum`/
    /// `_count` families. Served by the `metrics` wire op and written
    /// by `era-serve --metrics <path>`.
    pub fn prometheus(&self) -> String {
        let mut p = PromText::new();
        let counters: [(&str, &str, f64); 17] = [
            ("era_requests_admitted_total", "Requests admitted across shards.", self.admitted() as f64),
            ("era_requests_finished_total", "Requests finished successfully.", self.finished() as f64),
            ("era_requests_cancelled_total", "Requests retired by cancellation or deadline.", self.cancelled() as f64),
            ("era_requests_rejected_total", "Shard queue rejections plus pool-level admission rejections.", self.rejected() as f64),
            ("era_evals_total", "Fused model evaluations dispatched.", self.evals() as f64),
            ("era_rows_total", "Rows packed into fused evaluations.", self.rows() as f64),
            ("era_guided_requests_total", "Admitted requests using classifier-free guidance.", self.workloads().0 as f64),
            ("era_img2img_requests_total", "Admitted img2img partial-trajectory requests.", self.workloads().1 as f64),
            ("era_stochastic_requests_total", "Admitted stochastic (churned) sampling requests.", self.workloads().2 as f64),
            ("era_host_bytes_transferred_total", "Bytes crossing the host-engine boundary (slabs, resident ops, gathers).", self.host_bytes_transferred() as f64),
            ("era_early_stops_total", "Requests retired early by the convergence controller.", self.early_stops() as f64),
            ("era_degraded_requests_total", "Requests latched to their NFE floor (cap squeeze-in or deadline pressure).", self.degraded_requests() as f64),
            ("era_connections_accepted_total", "Client connections accepted across registered front ends.", self.conn.accepted_total as f64),
            ("era_connections_rejected_total", "Client connections refused at accept (connection cap or admission throttle).", self.conn.rejected_total as f64),
            ("era_backpressure_stalls_total", "Times a connection's read interest was parked on a full write queue.", self.conn.backpressure_stalls as f64),
            ("era_wire_bytes_in_total", "Wire bytes read from clients (request lines plus binary payloads).", self.conn.bytes_in as f64),
            ("era_wire_bytes_out_total", "Wire bytes written to clients (reply lines plus binary payloads).", self.conn.bytes_out as f64),
        ];
        for (name, help, v) in counters {
            p.family(name, help, "counter");
            p.value(name, &[], v);
        }
        let gauges: [(&str, &str, f64); 12] = [
            ("era_shards", "Coordinator shards in the pool.", self.shards() as f64),
            ("era_executors_per_shard", "Engine executor threads per shard.", self.executors_per_shard as f64),
            ("era_pipeline_depth", "Dispatch rounds allowed in flight per shard.", self.pipeline_depth as f64),
            ("era_inflight_requests", "Requests submitted but not yet retired.", self.per_shard.iter().map(|s| s.inflight_requests).sum::<usize>() as f64),
            ("era_inflight_rows", "Rows belonging to in-flight requests.", self.inflight_rows() as f64),
            ("era_inflight_slabs", "Slabs dispatched to executors and not yet routed back.", self.inflight_slabs() as f64),
            ("era_lanes", "Live solver lanes across shards.", self.lanes() as f64),
            ("era_resident_lanes", "Lanes currently stepping engine-resident.", self.resident_lanes() as f64),
            ("era_executor_busy_fraction", "Fraction of executor thread time spent evaluating.", self.executor_busy_fraction()),
            ("era_batch_occupancy_rows", "Mean rows per fused evaluation.", self.occupancy()),
            ("era_padding_fraction", "Fraction of executed rows that were bucket padding.", self.padding_fraction()),
            ("era_open_connections", "Client connections currently open across registered front ends.", self.conn.open_connections as f64),
        ];
        for (name, help, v) in gauges {
            p.family(name, help, "gauge");
            p.value(name, &[], v);
        }
        p.family(
            "era_request_latency_seconds",
            "End-to-end request latency percentiles over pooled samples.",
            "gauge",
        );
        p.value("era_request_latency_seconds", &[("quantile", "0.5")], self.p50_ms * 1e-3);
        p.value("era_request_latency_seconds", &[("quantile", "0.99")], self.p99_ms * 1e-3);
        p.family("era_mean_delta_eps", "Mean final ERA error measure (Eq. 15).", "gauge");
        p.value("era_mean_delta_eps", &[], self.mean_delta_eps());

        // Per-shard load view (labelled gauges).
        p.family("era_shard_inflight_rows", "Rows in flight per shard.", "gauge");
        for s in &self.per_shard {
            let shard = s.shard.to_string();
            p.value("era_shard_inflight_rows", &[("shard", &shard)], s.inflight_rows as f64);
        }
        p.family("era_shard_finished_total", "Finished requests per shard.", "counter");
        for s in &self.per_shard {
            let shard = s.shard.to_string();
            p.value("era_shard_finished_total", &[("shard", &shard)], s.finished as f64);
        }

        // Distribution families: pipeline depth and lane occupancy.
        p.family(
            "era_pipeline_depth_dispatches_total",
            "Dispatch rounds observed at each in-flight depth (last bucket absorbs deeper).",
            "counter",
        );
        for (i, &n) in self.depth_hist().iter().enumerate() {
            let depth = if i + 1 == DEPTH_HIST_BUCKETS {
                format!("{}+", i + 1)
            } else {
                format!("{}", i + 1)
            };
            p.value("era_pipeline_depth_dispatches_total", &[("depth", &depth)], n as f64);
        }
        p.family(
            "era_lane_occupancy_dispatches_total",
            "Lane dispatches by fused member count (last bucket absorbs deeper).",
            "counter",
        );
        for (i, &n) in self.lane_occ_hist().iter().enumerate() {
            let members = if i + 1 == LANE_OCC_BUCKETS {
                format!("{}+", i + 1)
            } else {
                format!("{}", i + 1)
            };
            p.value("era_lane_occupancy_dispatches_total", &[("members", &members)], n as f64);
        }
        p.family(
            "era_delivered_nfe_requests_total",
            "Delivered per-request NFE distribution (label is the bucket's inclusive upper bound; last bucket absorbs larger).",
            "counter",
        );
        for (i, &n) in self.delivered_nfe_hist().iter().enumerate() {
            let nfe = if i < NFE_HIST_BOUNDS.len() {
                NFE_HIST_BOUNDS[i].to_string()
            } else {
                format!(">{}", NFE_HIST_BOUNDS[NFE_HIST_BOUNDS.len() - 1])
            };
            p.value("era_delivered_nfe_requests_total", &[("nfe", &nfe)], n as f64);
        }

        // Per-stage latency histograms (queue / solver_step / eval /
        // finalize), pooled across shards.
        p.family(
            "era_stage_latency_seconds",
            "Per-stage latency: queue wait, host solver step, engine eval, finalize.",
            "histogram",
        );
        for (name, h) in STAGES.iter().zip(self.stage_hists().iter()) {
            p.histogram(
                "era_stage_latency_seconds",
                &[("stage", name)],
                &STAGE_BOUNDS,
                &h.buckets,
                h.sum_seconds,
                h.count,
            );
        }
        p.finish()
    }

    /// Pool-wide mean final `delta_eps`: summed sums over summed counts
    /// (a per-shard average would overweight lightly loaded shards).
    pub fn mean_delta_eps(&self) -> f64 {
        let sum: f64 = self.per_shard.iter().map(|s| s.delta_eps_sum).sum();
        let count: usize = self.per_shard.iter().map(|s| s.delta_eps_count).sum();
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    /// Pool-wide workload mix: (guided, img2img, stochastic) admissions.
    pub fn workloads(&self) -> (usize, usize, usize) {
        (
            self.per_shard.iter().map(|s| s.guided).sum(),
            self.per_shard.iter().map(|s| s.img2img).sum(),
            self.per_shard.iter().map(|s| s.stochastic).sum(),
        )
    }

    /// Pool-wide mean rows per fused evaluation.
    pub fn occupancy(&self) -> f64 {
        let evals = self.evals();
        if evals == 0 {
            0.0
        } else {
            self.rows() as f64 / evals as f64
        }
    }

    /// Pool-wide fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let rows = self.rows();
        let pad: usize = self.per_shard.iter().map(|s| s.padded_rows).sum();
        if rows + pad == 0 {
            0.0
        } else {
            pad as f64 / (rows + pad) as f64
        }
    }

    /// One-line summary for heartbeat logs / bench output.
    pub fn summary(&self) -> String {
        // Per-stage p50/p99 (pooled histograms) ride the heartbeat line
        // so operators can spot which stage regressed without scraping.
        let [queue, solver, eval, _finalize] = self.stage_hists();
        format!(
            "shards={} placement={} executors={} depth={} finished={} cancelled={} rejected={} \
             early_stops={} degraded={} evals={} rows={} occupancy={:.1} pad={:.1}% \
             exec_busy={:.0}% inflight_slabs={} lanes={} conns={}/{} stalls={} \
             wire={}B/{}B p50={:.1}ms p99={:.1}ms queue={}/{}ms step={}/{}ms eval={}/{}ms",
            self.shards(),
            self.placement,
            self.executors_per_shard,
            self.pipeline_depth,
            self.finished(),
            self.cancelled(),
            self.rejected(),
            self.early_stops(),
            self.degraded_requests(),
            self.evals(),
            self.rows(),
            self.occupancy(),
            100.0 * self.padding_fraction(),
            100.0 * self.executor_busy_fraction(),
            self.inflight_slabs(),
            self.lanes(),
            self.conn.open_connections,
            self.conn.accepted_total,
            self.conn.backpressure_stalls,
            self.conn.bytes_in,
            self.conn.bytes_out,
            self.p50_ms,
            self.p99_ms,
            fmt_quantile_ms(queue.quantile(0.5)),
            fmt_quantile_ms(queue.quantile(0.99)),
            fmt_quantile_ms(solver.quantile(0.5)),
            fmt_quantile_ms(solver.quantile(0.99)),
            fmt_quantile_ms(eval.quantile(0.5)),
            fmt_quantile_ms(eval.quantile(0.99)),
        )
    }

    /// The `stats` protocol response (field names kept compatible with
    /// the single-coordinator server).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("shards", Json::Num(self.shards() as f64)),
            ("placement", Json::Str(self.placement.to_string())),
            ("executors_per_shard", Json::Num(self.executors_per_shard as f64)),
            ("pipeline_depth", Json::Num(self.pipeline_depth as f64)),
            ("finished", Json::Num(self.finished() as f64)),
            ("admitted", Json::Num(self.admitted() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            ("cancelled", Json::Num(self.cancelled() as f64)),
            ("evals", Json::Num(self.evals() as f64)),
            ("rows", Json::Num(self.rows() as f64)),
            ("inflight_rows", Json::Num(self.inflight_rows() as f64)),
            ("occupancy", Json::Num(self.occupancy())),
            ("padding_fraction", Json::Num(self.padding_fraction())),
            ("guided", Json::Num(self.workloads().0 as f64)),
            ("img2img", Json::Num(self.workloads().1 as f64)),
            ("stochastic", Json::Num(self.workloads().2 as f64)),
            ("executor_busy_frac", Json::Num(self.executor_busy_fraction())),
            ("inflight_slabs", Json::Num(self.inflight_slabs() as f64)),
            ("host_bytes_transferred", Json::Num(self.host_bytes_transferred() as f64)),
            ("resident_lanes", Json::Num(self.resident_lanes() as f64)),
            (
                "depth_hist",
                Json::Arr(self.depth_hist().iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("lanes", Json::Num(self.lanes() as f64)),
            (
                "lane_occ_hist",
                Json::Arr(self.lane_occ_hist().iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("mean_delta_eps", Json::Num(self.mean_delta_eps())),
            ("early_stops", Json::Num(self.early_stops() as f64)),
            ("degraded_requests", Json::Num(self.degraded_requests() as f64)),
            (
                "delivered_nfe_hist",
                Json::Arr(
                    self.delivered_nfe_hist().iter().map(|&n| Json::Num(n as f64)).collect(),
                ),
            ),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("connections", self.conn.to_json()),
            (
                "stages",
                Json::obj(
                    STAGES
                        .iter()
                        .zip(self.stage_hists().iter())
                        .map(|(name, s)| (*name, s.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_sum_across_shards() {
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.requests_admitted.fetch_add(3, Ordering::Relaxed);
        b.requests_admitted.fetch_add(5, Ordering::Relaxed);
        a.evals.fetch_add(2, Ordering::Relaxed);
        b.evals.fetch_add(2, Ordering::Relaxed);
        a.rows.fetch_add(20, Ordering::Relaxed);
        b.rows.fetch_add(60, Ordering::Relaxed);
        a.record_finish(0.010, 0.0);
        b.record_finish(0.030, 0.0);
        let s = PoolStats::collect("round-robin", &[&a, &b], 1, 2, 3);
        assert_eq!(s.shards(), 2);
        assert_eq!(s.admitted(), 8);
        assert_eq!(s.finished(), 2);
        assert_eq!(s.evals(), 4);
        assert_eq!(s.rows(), 80);
        assert_eq!(s.rejected(), 1); // pool-level only here
        assert!((s.occupancy() - 20.0).abs() < 1e-9);
        assert!(s.summary().contains("shards=2"));
        assert!(s.summary().contains("executors=2 depth=3"));
        assert_eq!(s.to_json().get("finished").as_usize(), Some(2));
        assert_eq!(s.to_json().get("executors_per_shard").as_usize(), Some(2));
        assert_eq!(s.to_json().get("pipeline_depth").as_usize(), Some(3));
    }

    #[test]
    fn executor_clocks_and_depth_hist_merge_across_shards() {
        // Merge rules: clocks and histograms sum; the busy fraction is
        // derived from the summed clocks, never averaged per shard —
        // a mostly-idle shard must drag the pooled fraction down in
        // proportion to its clock time, not by half.
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.executor_busy_nanos.fetch_add(900, Ordering::Relaxed);
        a.executor_idle_nanos.fetch_add(100, Ordering::Relaxed);
        b.executor_busy_nanos.fetch_add(0, Ordering::Relaxed);
        b.executor_idle_nanos.fetch_add(3000, Ordering::Relaxed);
        a.inflight_slabs.fetch_add(3, Ordering::Relaxed);
        b.inflight_slabs.fetch_add(2, Ordering::Relaxed);
        a.observe_depth(1);
        a.observe_depth(2);
        b.observe_depth(2);
        b.observe_depth(99); // clamps into the last bucket
        let s = PoolStats::collect("round-robin", &[&a, &b], 0, 2, 2);
        assert_eq!(s.inflight_slabs(), 5);
        // 900 busy out of 4000 total clock — not the 0.45 a naive
        // per-shard average of (0.9, 0.0) would give.
        assert!((s.executor_busy_fraction() - 0.225).abs() < 1e-12);
        let h = s.depth_hist();
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 2);
        assert_eq!(h[DEPTH_HIST_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<usize>(), 4);
        // Per-shard views keep their own fractions.
        assert!((s.per_shard[0].executor_busy_fraction() - 0.9).abs() < 1e-12);
        assert_eq!(s.per_shard[1].executor_busy_fraction(), 0.0);
        let json = s.per_shard[0].to_json();
        assert_eq!(json.get("inflight_slabs").as_usize(), Some(3));
        assert_eq!(
            s.to_json().get("depth_hist").as_arr().map(|v| v.len()),
            Some(DEPTH_HIST_BUCKETS)
        );
    }

    #[test]
    fn lane_gauges_and_delta_eps_merge_across_shards() {
        // Merge rules: the lane gauge and occupancy histogram sum;
        // mean_delta_eps derives from summed sums over summed counts —
        // never a per-shard average, which would overweight a shard
        // that finished one request.
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.lanes.store(3, Ordering::Relaxed);
        b.lanes.store(2, Ordering::Relaxed);
        a.observe_lane_occupancy(1);
        a.observe_lane_occupancy(4);
        b.observe_lane_occupancy(4);
        b.observe_lane_occupancy(99); // clamps into the last bucket
        for _ in 0..3 {
            a.record_delta_eps(0.1);
        }
        b.record_delta_eps(0.5);
        let s = PoolStats::collect("round-robin", &[&a, &b], 0, 1, 1);
        assert_eq!(s.lanes(), 5);
        let h = s.lane_occ_hist();
        assert_eq!(h[0], 1);
        assert_eq!(h[3], 2);
        assert_eq!(h[LANE_OCC_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<usize>(), 4);
        // (3 * 0.1 + 0.5) / 4 = 0.2 — not the 0.3 a per-shard average
        // of (0.1, 0.5) would give.
        assert!((s.mean_delta_eps() - 0.2).abs() < 1e-12, "{}", s.mean_delta_eps());
        assert!((s.per_shard[0].mean_delta_eps() - 0.1).abs() < 1e-12);
        assert!(s.summary().contains("lanes=5"));
        let json = s.to_json();
        assert_eq!(json.get("lanes").as_usize(), Some(5));
        assert_eq!(json.get("lane_occ_hist").as_arr().map(|v| v.len()), Some(LANE_OCC_BUCKETS));
        assert!((json.get("mean_delta_eps").as_f64().unwrap() - 0.2).abs() < 1e-12);
        let sj = s.per_shard[1].to_json();
        assert_eq!(sj.get("lanes").as_usize(), Some(2));
        assert!((sj.get("mean_delta_eps").as_f64().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn host_bytes_and_resident_lanes_merge_across_shards() {
        // Merge rules: the byte counter and resident-lane gauge both
        // sum across shards; per-shard views stay unmerged.
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.host_bytes_transferred.fetch_add(4096, Ordering::Relaxed);
        b.host_bytes_transferred.fetch_add(1024, Ordering::Relaxed);
        a.resident_lanes.fetch_add(2, Ordering::Relaxed);
        b.resident_lanes.fetch_add(1, Ordering::Relaxed);
        let s = PoolStats::collect("round-robin", &[&a, &b], 0, 1, 1);
        assert_eq!(s.host_bytes_transferred(), 5120);
        assert_eq!(s.resident_lanes(), 3);
        assert_eq!(s.per_shard[0].host_bytes_transferred, 4096);
        assert_eq!(s.per_shard[1].resident_lanes, 1);
        let json = s.to_json();
        assert_eq!(json.get("host_bytes_transferred").as_usize(), Some(5120));
        assert_eq!(json.get("resident_lanes").as_usize(), Some(3));
        let sj = s.per_shard[1].to_json();
        assert_eq!(sj.get("host_bytes_transferred").as_usize(), Some(1024));
        assert_eq!(sj.get("resident_lanes").as_usize(), Some(1));
        let text = s.prometheus();
        assert!(text.contains("# TYPE era_host_bytes_transferred_total counter\n"), "{text}");
        assert!(text.contains("era_host_bytes_transferred_total 5120\n"), "{text}");
        assert!(text.contains("# TYPE era_resident_lanes gauge\n"), "{text}");
        assert!(text.contains("era_resident_lanes 3\n"), "{text}");
    }

    #[test]
    fn conn_snapshot_rides_stats_json_summary_and_prometheus() {
        // Connection counters arrive pre-merged (ConnSnapshot::merge
        // sums every field across front ends) and fan out to all three
        // renderings; the no-front-end default stays all-zero.
        use crate::coordinator::ConnSnapshot;
        let a = Telemetry::new();
        let zero = PoolStats::collect("round-robin", &[&a], 0, 1, 1);
        assert_eq!(zero.conn, ConnSnapshot::default());
        assert_eq!(zero.to_json().get("connections").get("open").as_usize(), Some(0));

        let mut conn = ConnSnapshot {
            open_connections: 3,
            accepted_total: 10,
            rejected_total: 1,
            backpressure_stalls: 2,
            bytes_in: 100,
            bytes_out: 4000,
        };
        conn.merge(&ConnSnapshot {
            open_connections: 4,
            accepted_total: 20,
            rejected_total: 2,
            backpressure_stalls: 5,
            bytes_in: 28,
            bytes_out: 96,
        });
        let s = PoolStats::collect_with_conns("round-robin", &[&a], 0, 1, 1, conn);
        assert_eq!(s.conn.open_connections, 7);
        assert_eq!(s.conn.accepted_total, 30);
        assert_eq!(s.conn.rejected_total, 3);
        assert_eq!(s.conn.backpressure_stalls, 7);
        let json = s.to_json();
        assert_eq!(json.get("connections").get("open").as_usize(), Some(7));
        assert_eq!(json.get("connections").get("accepted").as_usize(), Some(30));
        assert_eq!(json.get("connections").get("rejected").as_usize(), Some(3));
        assert_eq!(json.get("connections").get("backpressure_stalls").as_usize(), Some(7));
        assert_eq!(json.get("connections").get("bytes_in").as_usize(), Some(128));
        assert_eq!(json.get("connections").get("bytes_out").as_usize(), Some(4096));
        assert!(s.summary().contains("conns=7/30 stalls=7"), "{}", s.summary());
        assert!(s.summary().contains("wire=128B/4096B"), "{}", s.summary());
        let text = s.prometheus();
        assert!(text.contains("# TYPE era_connections_accepted_total counter\n"), "{text}");
        assert!(text.contains("era_connections_accepted_total 30\n"), "{text}");
        assert!(text.contains("era_connections_rejected_total 3\n"), "{text}");
        assert!(text.contains("era_backpressure_stalls_total 7\n"), "{text}");
        assert!(text.contains("# TYPE era_wire_bytes_in_total counter\n"), "{text}");
        assert!(text.contains("era_wire_bytes_in_total 128\n"), "{text}");
        assert!(text.contains("era_wire_bytes_out_total 4096\n"), "{text}");
        assert!(text.contains("# TYPE era_open_connections gauge\n"), "{text}");
        assert!(text.contains("era_open_connections 7\n"), "{text}");
    }

    #[test]
    fn stage_histograms_merge_elementwise_across_shards() {
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.stage_eval.observe_seconds(1e-4);
        a.stage_eval.observe_seconds(1e-2);
        b.stage_eval.observe_seconds(1e-4);
        b.stage_solver.observe_seconds(2e-5);
        let s = PoolStats::collect("round-robin", &[&a, &b], 0, 1, 1);
        let [queue, solver, eval, finalize] = s.stage_hists();
        assert_eq!(eval.count, 3);
        assert_eq!(eval.buckets[2], 2, "two 1e-4 evals pooled");
        assert_eq!(solver.count, 1);
        assert_eq!(queue.count, 0);
        assert_eq!(finalize.count, 0);
        // Per-shard snapshots stay unmerged.
        assert_eq!(s.per_shard[0].stages[2].count, 2);
        assert_eq!(s.per_shard[1].stages[2].count, 1);
        let json = s.to_json();
        assert_eq!(
            json.get("stages").get("eval").get("count").as_usize(),
            Some(3),
            "merged stage hists ride the stats payload"
        );
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.requests_admitted.fetch_add(3, Ordering::Relaxed);
        b.requests_admitted.fetch_add(1, Ordering::Relaxed);
        a.record_finish(0.010, 0.002);
        a.stage_eval.observe_seconds(2e-3);
        b.stage_eval.observe_seconds(2e-3);
        a.observe_depth(1);
        a.observe_lane_occupancy(3);
        let s = PoolStats::collect("least-loaded", &[&a, &b], 0, 2, 2);
        let text = s.prometheus();
        // Families carry HELP/TYPE headers and the era_ prefix.
        assert!(text.contains("# TYPE era_requests_admitted_total counter\n"), "{text}");
        assert!(text.contains("era_requests_admitted_total 4\n"), "{text}");
        assert!(text.contains("era_requests_finished_total 1\n"));
        assert!(text.contains("# TYPE era_inflight_rows gauge\n"));
        assert!(text.contains("era_shards 2\n"));
        assert!(text.contains("era_shard_finished_total{shard=\"0\"} 1\n"));
        assert!(text.contains("era_shard_finished_total{shard=\"1\"} 0\n"));
        // Distributions: depth / lane occupancy labelled counters.
        assert!(text.contains("era_pipeline_depth_dispatches_total{depth=\"1\"} 1\n"));
        assert!(text.contains(&format!(
            "era_pipeline_depth_dispatches_total{{depth=\"{DEPTH_HIST_BUCKETS}+\"}} 0\n"
        )));
        assert!(text.contains("era_lane_occupancy_dispatches_total{members=\"3\"} 1\n"));
        // Per-stage latency histograms: cumulative buckets + +Inf,
        // pooled across shards (two 2e-3 eval observations).
        assert!(text.contains("# TYPE era_stage_latency_seconds histogram\n"));
        assert!(
            text.contains("era_stage_latency_seconds_bucket{stage=\"eval\",le=\"+Inf\"} 2\n"),
            "{text}"
        );
        assert!(text.contains("era_stage_latency_seconds_count{stage=\"eval\"} 2\n"));
        assert!(text.contains("era_stage_latency_seconds_count{stage=\"queue\"} 1\n"));
        // f64 Display renders 1e-5 in decimal form.
        assert!(
            text.contains("era_stage_latency_seconds_bucket{stage=\"solver_step\",le=\"0.00001\"} 0\n"),
            "{text}"
        );
        // Every sample line belongs to an era_-prefixed family.
        assert!(text
            .lines()
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .all(|l| l.starts_with("era_")));
    }

    #[test]
    fn qos_counters_and_nfe_hist_merge_across_shards() {
        // Merge rules: early-stop / degraded counters and the
        // delivered-NFE histogram all sum element-wise across shards.
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.early_stops.fetch_add(2, Ordering::Relaxed);
        b.early_stops.fetch_add(1, Ordering::Relaxed);
        a.degraded_requests.fetch_add(1, Ordering::Relaxed);
        a.observe_delivered_nfe(4);
        a.observe_delivered_nfe(24);
        b.observe_delivered_nfe(4);
        b.observe_delivered_nfe(1000); // clamps into the overflow bucket
        let s = PoolStats::collect("round-robin", &[&a, &b], 0, 1, 1);
        assert_eq!(s.early_stops(), 3);
        assert_eq!(s.degraded_requests(), 1);
        let h = s.delivered_nfe_hist();
        assert_eq!(h[2], 2, "two nfe=4 deliveries pooled");
        assert_eq!(h[5], 1, "nfe=24 lands in the le=32 bucket");
        assert_eq!(h[NFE_HIST_BUCKETS - 1], 1);
        assert_eq!(h.iter().sum::<u64>(), 4);
        assert!(s.summary().contains("early_stops=3 degraded=1"), "{}", s.summary());
        let json = s.to_json();
        assert_eq!(json.get("early_stops").as_usize(), Some(3));
        assert_eq!(json.get("degraded_requests").as_usize(), Some(1));
        assert_eq!(
            json.get("delivered_nfe_hist").as_arr().map(|v| v.len()),
            Some(NFE_HIST_BUCKETS)
        );
        let sj = s.per_shard[1].to_json();
        assert_eq!(sj.get("early_stops").as_usize(), Some(1));
        assert_eq!(sj.get("degraded_requests").as_usize(), Some(0));
        let text = s.prometheus();
        assert!(text.contains("# TYPE era_early_stops_total counter\n"), "{text}");
        assert!(text.contains("era_early_stops_total 3\n"), "{text}");
        assert!(text.contains("era_degraded_requests_total 1\n"), "{text}");
        assert!(text.contains("era_delivered_nfe_requests_total{nfe=\"4\"} 2\n"), "{text}");
        assert!(text.contains("era_delivered_nfe_requests_total{nfe=\">64\"} 1\n"), "{text}");
    }

    #[test]
    fn summary_renders_overflow_stage_quantiles_as_inf() {
        // A stage observation past the last finite bound must surface
        // as +Inf on the heartbeat line, not a made-up finite figure.
        let a = Telemetry::new();
        a.stage_queue.observe_seconds(STAGE_BOUNDS[STAGE_BOUNDS.len() - 1] * 2.0);
        let s = PoolStats::collect("round-robin", &[&a], 0, 1, 1);
        assert!(s.summary().contains("queue=+Inf/+Infms"), "{}", s.summary());
        // Stages with no samples keep the plain zero rendering.
        assert!(s.summary().contains("eval=0.00/0.00ms"), "{}", s.summary());
    }

    #[test]
    fn percentiles_are_pooled_not_averaged() {
        // Shard a: 49 fast requests; shard b: 1 slow one. The pooled
        // p50 must sit with the fast mass, not between the shards.
        let a = Telemetry::new();
        let b = Telemetry::new();
        for _ in 0..49 {
            a.record_finish(0.010, 0.0);
        }
        b.record_finish(1.0, 0.0);
        let s = PoolStats::collect("least-loaded", &[&a, &b], 0, 1, 1);
        assert!((s.p50_ms - 10.0).abs() < 1e-6, "p50 {}", s.p50_ms);
        assert!(s.p99_ms > 500.0, "p99 {}", s.p99_ms);
    }

    #[test]
    fn empty_pool_stats_are_zero() {
        let a = Telemetry::new();
        let s = PoolStats::collect("affinity", &[&a], 0, 1, 1);
        assert_eq!(s.finished(), 0);
        assert_eq!(s.occupancy(), 0.0);
        assert_eq!(s.p50_ms, 0.0);
        assert_eq!(s.to_json().get("shards").as_usize(), Some(1));
    }
}
