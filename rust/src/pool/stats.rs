//! Aggregated pool statistics: one merged view over N shard
//! [`Telemetry`] instances.
//!
//! Counters add; occupancy/padding re-derive from the summed rows and
//! evals; percentiles are computed over the *pooled* raw latency
//! samples (averaging per-shard percentiles would be wrong whenever
//! shards carry uneven load).

use std::sync::atomic::Ordering;

use crate::coordinator::telemetry::sorted_percentile;
use crate::coordinator::Telemetry;
use crate::json::Json;

/// One shard's counters at snapshot time.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub shard: usize,
    pub admitted: usize,
    pub finished: usize,
    pub cancelled: usize,
    pub rejected: usize,
    pub evals: usize,
    pub rows: usize,
    pub padded_rows: usize,
    pub inflight_requests: usize,
    pub inflight_rows: usize,
    /// Workload mix (see [`Telemetry`]): guided / img2img / stochastic
    /// requests admitted on this shard.
    pub guided: usize,
    pub img2img: usize,
    pub stochastic: usize,
}

impl ShardStats {
    pub fn from_telemetry(shard: usize, t: &Telemetry) -> ShardStats {
        ShardStats {
            shard,
            admitted: t.requests_admitted.load(Ordering::Relaxed),
            finished: t.requests_finished.load(Ordering::Relaxed),
            cancelled: t.requests_cancelled.load(Ordering::Relaxed),
            rejected: t.requests_rejected.load(Ordering::Relaxed),
            evals: t.evals.load(Ordering::Relaxed),
            rows: t.rows.load(Ordering::Relaxed),
            padded_rows: t.padded_rows.load(Ordering::Relaxed),
            inflight_requests: t.inflight_requests.load(Ordering::Relaxed),
            inflight_rows: t.inflight_rows.load(Ordering::Relaxed),
            guided: t.guided_requests.load(Ordering::Relaxed),
            img2img: t.img2img_requests.load(Ordering::Relaxed),
            stochastic: t.stochastic_requests.load(Ordering::Relaxed),
        }
    }

    /// Mean rows per fused evaluation on this shard.
    pub fn occupancy(&self) -> f64 {
        if self.evals == 0 {
            0.0
        } else {
            self.rows as f64 / self.evals as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("finished", Json::Num(self.finished as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("evals", Json::Num(self.evals as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("inflight_requests", Json::Num(self.inflight_requests as f64)),
            ("inflight_rows", Json::Num(self.inflight_rows as f64)),
            ("occupancy", Json::Num(self.occupancy())),
            ("guided", Json::Num(self.guided as f64)),
            ("img2img", Json::Num(self.img2img as f64)),
            ("stochastic", Json::Num(self.stochastic as f64)),
        ])
    }
}

/// Merged snapshot over every shard of a pool.
#[derive(Clone, Debug)]
pub struct PoolStats {
    pub placement: &'static str,
    pub per_shard: Vec<ShardStats>,
    /// Requests the pool itself refused (global admission control or
    /// every shard's queue full) — shard-level queue rejections are in
    /// `per_shard[i].rejected`.
    pub pool_rejected: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl PoolStats {
    /// Snapshot and merge the given shards' telemetry.
    pub fn collect(
        placement: &'static str,
        telemetries: &[&Telemetry],
        pool_rejected: usize,
    ) -> PoolStats {
        let per_shard: Vec<ShardStats> = telemetries
            .iter()
            .enumerate()
            .map(|(i, t)| ShardStats::from_telemetry(i, t))
            .collect();
        let mut lat: Vec<f64> = Vec::new();
        for t in telemetries {
            lat.extend(t.latency_samples());
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        PoolStats {
            placement,
            per_shard,
            pool_rejected,
            p50_ms: 1e3 * sorted_percentile(&lat, 0.5),
            p99_ms: 1e3 * sorted_percentile(&lat, 0.99),
        }
    }

    pub fn shards(&self) -> usize {
        self.per_shard.len()
    }

    pub fn admitted(&self) -> usize {
        self.per_shard.iter().map(|s| s.admitted).sum()
    }

    pub fn finished(&self) -> usize {
        self.per_shard.iter().map(|s| s.finished).sum()
    }

    pub fn cancelled(&self) -> usize {
        self.per_shard.iter().map(|s| s.cancelled).sum()
    }

    /// Shard queue rejections plus pool-level rejections.
    pub fn rejected(&self) -> usize {
        self.per_shard.iter().map(|s| s.rejected).sum::<usize>() + self.pool_rejected
    }

    pub fn evals(&self) -> usize {
        self.per_shard.iter().map(|s| s.evals).sum()
    }

    pub fn rows(&self) -> usize {
        self.per_shard.iter().map(|s| s.rows).sum()
    }

    pub fn inflight_rows(&self) -> usize {
        self.per_shard.iter().map(|s| s.inflight_rows).sum()
    }

    /// Pool-wide workload mix: (guided, img2img, stochastic) admissions.
    pub fn workloads(&self) -> (usize, usize, usize) {
        (
            self.per_shard.iter().map(|s| s.guided).sum(),
            self.per_shard.iter().map(|s| s.img2img).sum(),
            self.per_shard.iter().map(|s| s.stochastic).sum(),
        )
    }

    /// Pool-wide mean rows per fused evaluation.
    pub fn occupancy(&self) -> f64 {
        let evals = self.evals();
        if evals == 0 {
            0.0
        } else {
            self.rows() as f64 / evals as f64
        }
    }

    /// Pool-wide fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let rows = self.rows();
        let pad: usize = self.per_shard.iter().map(|s| s.padded_rows).sum();
        if rows + pad == 0 {
            0.0
        } else {
            pad as f64 / (rows + pad) as f64
        }
    }

    /// One-line summary for heartbeat logs / bench output.
    pub fn summary(&self) -> String {
        format!(
            "shards={} placement={} finished={} cancelled={} rejected={} evals={} rows={} \
             occupancy={:.1} pad={:.1}% p50={:.1}ms p99={:.1}ms",
            self.shards(),
            self.placement,
            self.finished(),
            self.cancelled(),
            self.rejected(),
            self.evals(),
            self.rows(),
            self.occupancy(),
            100.0 * self.padding_fraction(),
            self.p50_ms,
            self.p99_ms,
        )
    }

    /// The `stats` protocol response (field names kept compatible with
    /// the single-coordinator server).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("shards", Json::Num(self.shards() as f64)),
            ("placement", Json::Str(self.placement.to_string())),
            ("finished", Json::Num(self.finished() as f64)),
            ("admitted", Json::Num(self.admitted() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            ("cancelled", Json::Num(self.cancelled() as f64)),
            ("evals", Json::Num(self.evals() as f64)),
            ("rows", Json::Num(self.rows() as f64)),
            ("inflight_rows", Json::Num(self.inflight_rows() as f64)),
            ("occupancy", Json::Num(self.occupancy())),
            ("padding_fraction", Json::Num(self.padding_fraction())),
            ("guided", Json::Num(self.workloads().0 as f64)),
            ("img2img", Json::Num(self.workloads().1 as f64)),
            ("stochastic", Json::Num(self.workloads().2 as f64)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_sum_across_shards() {
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.requests_admitted.fetch_add(3, Ordering::Relaxed);
        b.requests_admitted.fetch_add(5, Ordering::Relaxed);
        a.evals.fetch_add(2, Ordering::Relaxed);
        b.evals.fetch_add(2, Ordering::Relaxed);
        a.rows.fetch_add(20, Ordering::Relaxed);
        b.rows.fetch_add(60, Ordering::Relaxed);
        a.record_finish(0.010, 0.0);
        b.record_finish(0.030, 0.0);
        let s = PoolStats::collect("round-robin", &[&a, &b], 1);
        assert_eq!(s.shards(), 2);
        assert_eq!(s.admitted(), 8);
        assert_eq!(s.finished(), 2);
        assert_eq!(s.evals(), 4);
        assert_eq!(s.rows(), 80);
        assert_eq!(s.rejected(), 1); // pool-level only here
        assert!((s.occupancy() - 20.0).abs() < 1e-9);
        assert!(s.summary().contains("shards=2"));
        assert_eq!(s.to_json().get("finished").as_usize(), Some(2));
    }

    #[test]
    fn percentiles_are_pooled_not_averaged() {
        // Shard a: 49 fast requests; shard b: 1 slow one. The pooled
        // p50 must sit with the fast mass, not between the shards.
        let a = Telemetry::new();
        let b = Telemetry::new();
        for _ in 0..49 {
            a.record_finish(0.010, 0.0);
        }
        b.record_finish(1.0, 0.0);
        let s = PoolStats::collect("least-loaded", &[&a, &b], 0);
        assert!((s.p50_ms - 10.0).abs() < 1e-6, "p50 {}", s.p50_ms);
        assert!(s.p99_ms > 500.0, "p99 {}", s.p99_ms);
    }

    #[test]
    fn empty_pool_stats_are_zero() {
        let a = Telemetry::new();
        let s = PoolStats::collect("affinity", &[&a], 0);
        assert_eq!(s.finished(), 0);
        assert_eq!(s.occupancy(), 0.0);
        assert_eq!(s.p50_ms, 0.0);
        assert_eq!(s.to_json().get("shards").as_usize(), Some(1));
    }
}
