//! Rust mirrors of the Python synthetic manifolds (python/compile/datasets.py).
//!
//! Used by tests, the workload generator, and the qualitative figures.
//! Distribution-level equality with the Python side is what matters (the
//! FID reference moments ship in the manifest, computed once in Python);
//! tests here pin the same moment/support invariants the pytest side pins.

use crate::rng::Rng;
use crate::tensor::Tensor;

/// Dataset identifiers matching the artifact manifest keys.
pub const DATASETS: [&str; 5] = ["gmm8", "checkerboard", "swissroll", "rings", "patches64"];

/// Data dimension per dataset.
pub fn dim(name: &str) -> Option<usize> {
    match name {
        "gmm8" | "checkerboard" | "swissroll" | "rings" => Some(2),
        "patches64" => Some(64),
        _ => None,
    }
}

/// The paper dataset each manifold stands in for (see DESIGN.md §2).
pub fn stands_in_for(name: &str) -> &'static str {
    match name {
        "gmm8" => "CIFAR-10",
        "checkerboard" => "LSUN-Church",
        "swissroll" => "LSUN-Bedroom",
        "rings" => "CelebA",
        "patches64" => "high-dim stress test",
        _ => "?",
    }
}

/// Sample `n` points. `basis` is required for `patches64` (from the
/// manifest; the Python exporter owns the canonical one).
pub fn sample(name: &str, rng: &mut Rng, n: usize, basis: Option<&[f32]>) -> Tensor {
    match name {
        "gmm8" => gmm8(rng, n),
        "checkerboard" => checkerboard(rng, n),
        "swissroll" => swissroll(rng, n),
        "rings" => rings(rng, n),
        "patches64" => patches64(rng, n, basis.expect("patches64 needs a basis")),
        _ => panic!("unknown dataset {name}"),
    }
}

/// Mode centers of gmm8 (used by the coverage metric).
pub fn gmm8_modes() -> Vec<Vec<f64>> {
    (0..8)
        .map(|i| {
            let a = 2.0 * std::f64::consts::PI * i as f64 / 8.0;
            vec![2.0 * a.cos(), 2.0 * a.sin()]
        })
        .collect()
}

fn gmm8(rng: &mut Rng, n: usize) -> Tensor {
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let mode = rng.below(8) as f64;
        let a = 2.0 * std::f64::consts::PI * mode / 8.0;
        data.push((2.0 * a.cos() + 0.15 * rng.normal()) as f32);
        data.push((2.0 * a.sin() + 0.15 * rng.normal()) as f32);
    }
    Tensor::from_vec(data, n, 2)
}

fn checkerboard(rng: &mut Rng, n: usize) -> Tensor {
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let x = rng.uniform_in(-2.0, 2.0);
        let y_cell = rng.uniform();
        let row = rng.below(2) as f64;
        let col = (x + 2.0).floor();
        let y = y_cell + 2.0 * row - 2.0 + col.rem_euclid(2.0);
        data.push(x as f32);
        data.push(y as f32);
    }
    Tensor::from_vec(data, n, 2)
}

fn swissroll(rng: &mut Rng, n: usize) -> Tensor {
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let t = rng.uniform().sqrt();
        let theta = 3.0 * std::f64::consts::PI * t + 0.5 * std::f64::consts::PI;
        let r = 0.6 * t + 0.08;
        data.push((2.4 * r * theta.cos() + 0.05 * rng.normal()) as f32);
        data.push((2.4 * r * theta.sin() + 0.05 * rng.normal()) as f32);
    }
    Tensor::from_vec(data, n, 2)
}

fn rings(rng: &mut Rng, n: usize) -> Tensor {
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let radius = if rng.uniform() < 0.5 { 0.8 } else { 1.8 };
        let theta = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
        let r = radius + 0.07 * rng.normal();
        data.push((r * theta.cos()) as f32);
        data.push((r * theta.sin()) as f32);
    }
    Tensor::from_vec(data, n, 2)
}

fn patches64(rng: &mut Rng, n: usize, basis: &[f32]) -> Tensor {
    assert_eq!(basis.len(), 64 * 8, "patches64 basis must be 64x8 row-major");
    let mut data = Vec::with_capacity(n * 64);
    for _ in 0..n {
        let z: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        for i in 0..64 {
            let mut acc = 0.0f64;
            for (k, &zk) in z.iter().enumerate() {
                acc += basis[i * 8 + k] as f64 * zk;
            }
            data.push((1.5 * acc).tanh() as f32);
        }
    }
    Tensor::from_vec(data, n, 64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match() {
        for name in DATASETS {
            assert!(dim(name).is_some(), "{name}");
        }
        assert_eq!(dim("gmm8"), Some(2));
        assert_eq!(dim("patches64"), Some(64));
        assert_eq!(dim("nope"), None);
    }

    #[test]
    fn gmm8_on_circle() {
        let mut rng = Rng::new(0);
        let x = gmm8(&mut rng, 5000);
        let mut near = 0;
        for r in 0..x.rows() {
            let row = x.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 2.0).abs() < 0.6 {
                near += 1;
            }
        }
        assert!(near as f64 / 5000.0 > 0.99);
    }

    #[test]
    fn gmm8_covers_all_modes() {
        let mut rng = Rng::new(1);
        let x = gmm8(&mut rng, 4000);
        assert!((crate::metrics::mode_coverage(&x, &gmm8_modes(), 0.45) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn checkerboard_parity() {
        let mut rng = Rng::new(2);
        let x = checkerboard(&mut rng, 5000);
        let mut ok = 0;
        for r in 0..x.rows() {
            let row = x.row(r);
            assert!(row[0].abs() <= 2.0 + 1e-5);
            let cx = (row[0] as f64 + 2.0).floor();
            let cy = (row[1] as f64 + 2.0).clamp(0.0, 3.999).floor();
            if ((cx + cy) as i64) % 2 == 0 {
                ok += 1;
            }
        }
        assert!(ok as f64 / 5000.0 > 0.995);
    }

    #[test]
    fn rings_two_radii_balanced() {
        let mut rng = Rng::new(3);
        let x = rings(&mut rng, 8000);
        let (mut inner, mut outer) = (0, 0);
        for r in 0..x.rows() {
            let row = x.row(r);
            let rad = ((row[0] as f64).powi(2) + (row[1] as f64).powi(2)).sqrt();
            if (rad - 0.8).abs() < 0.3 {
                inner += 1;
            } else if (rad - 1.8).abs() < 0.3 {
                outer += 1;
            }
        }
        assert!((inner + outer) as f64 / 8000.0 > 0.99);
        let frac = inner as f64 / 8000.0;
        assert!(frac > 0.45 && frac < 0.55, "{frac}");
    }

    #[test]
    fn patches64_bounded() {
        let mut rng = Rng::new(4);
        // An arbitrary normalised basis works for the invariants.
        let basis: Vec<f32> = (0..512).map(|i| ((i % 13) as f32 - 6.0) / 20.0).collect();
        let x = patches64(&mut rng, 200, &basis);
        assert_eq!(x.cols(), 64);
        assert!(x.as_slice().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn gmm8_moments_match_python_reference() {
        // Python: E[x]=0, var = 2 + 0.15^2 per axis (test_datasets.py).
        let mut rng = Rng::new(5);
        let x = gmm8(&mut rng, 50_000);
        let mu = x.col_means();
        let cov = x.covariance();
        assert!(mu[0].abs() < 0.05 && mu[1].abs() < 0.05);
        assert!((cov[0] - 2.0225).abs() < 0.1, "{}", cov[0]);
        assert!((cov[3] - 2.0225).abs() < 0.1, "{}", cov[3]);
    }

    #[test]
    #[should_panic(expected = "unknown dataset")]
    fn unknown_dataset_panics() {
        let mut rng = Rng::new(0);
        let _ = sample("nope", &mut rng, 1, None);
    }
}
