//! Minimal benchmarking harness (the offline registry ships no
//! criterion). Used by the `harness = false` bench targets.
//!
//! Methodology: warmup runs, then adaptively sized measurement batches
//! until either the time budget or the iteration cap is hit; reports
//! min / median / mean / p90 over per-iteration times. Medians are
//! robust to the one-core box's scheduler noise. Also prints a
//! machine-greppable `BENCHLINE` per case so `make bench` output can be
//! diffed across perf iterations (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

/// One benchmark's collected statistics (seconds per iteration).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub p90: f64,
}

impl Stats {
    fn from_times(name: &str, mut times: Vec<f64>) -> Stats {
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            min: times[0],
            median: times[n / 2],
            mean,
            p90: times[(n - 1).min(n * 9 / 10)],
        }
    }

    pub fn line(&self) -> String {
        format!(
            "BENCHLINE {name} iters={iters} min={min:.6e} median={median:.6e} \
             mean={mean:.6e} p90={p90:.6e}",
            name = self.name,
            iters = self.iters,
            min = self.min,
            median = self.median,
            mean = self.mean,
            p90 = self.p90,
        )
    }
}

/// Bench runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub time_budget: Duration,
}

impl Default for Config {
    fn default() -> Self {
        Config { warmup_iters: 3, max_iters: 200, time_budget: Duration::from_secs(5) }
    }
}

/// Bench group: runs cases, pretty-prints, collects stats.
pub struct Bench {
    config: Config,
    results: Vec<Stats>,
}

impl Bench {
    pub fn new() -> Bench {
        // Respect a quick mode for CI-ish runs: ERA_BENCH_QUICK=1.
        let quick = std::env::var("ERA_BENCH_QUICK").is_ok();
        let config = if quick {
            Config { warmup_iters: 1, max_iters: 10, time_budget: Duration::from_millis(500) }
        } else {
            Config::default()
        };
        Bench { config, results: Vec::new() }
    }

    pub fn with_config(config: Config) -> Bench {
        Bench { config, results: Vec::new() }
    }

    /// Time `f` (which should return something to keep the optimiser
    /// honest; its result is black-boxed).
    pub fn case<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Stats {
        for _ in 0..self.config.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let budget_end = Instant::now() + self.config.time_budget;
        while times.len() < self.config.max_iters
            && (times.len() < 5 || Instant::now() < budget_end)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let stats = Stats::from_times(name, times);
        println!("{:<48} median {:>10.3?}  (n={})", name, secs(stats.median), stats.iters);
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

fn secs(s: f64) -> Duration {
    Duration::from_secs_f64(s.max(0.0))
}

/// Opaque value sink (stable alternative to `std::hint::black_box` for
/// older toolchains; thin wrapper here).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench::with_config(Config {
            warmup_iters: 1,
            max_iters: 8,
            time_budget: Duration::from_millis(200),
        });
        let s = b.case("noop", || 1 + 1).clone();
        assert_eq!(s.name, "noop");
        assert!(s.iters >= 5);
        assert!(s.min <= s.median && s.median <= s.p90);
        assert!(s.line().starts_with("BENCHLINE noop"));
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn ordering_of_two_cases() {
        let mut b = Bench::with_config(Config {
            warmup_iters: 1,
            max_iters: 6,
            time_budget: Duration::from_millis(300),
        });
        let fast = b.case("fast", || 0u64).median;
        let slow = b
            .case("slow", || {
                let mut acc = 0u64;
                for i in 0..200_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            })
            .median;
        assert!(slow > fast);
    }
}
