//! Resident-lane golden equivalence: device residency changes *where*
//! lane state lives and how many bytes cross the host↔engine boundary
//! per step — never the numerics.
//!
//! Every scenario runs twice over the same `AnalyticGmm` denoiser:
//! once against a plain `MockBank` (pure slab path: stacked iterate
//! ships both ways every step) and once against
//! `MockBank::with_residency()` (iterate uploads once; steps ship
//! coefficient-sized ops). Samples must be **bitwise identical** —
//! the resident engine applies the same fused kernel wrappers in the
//! same accumulation order. Divergence here means residency changed
//! the solver, not just its traffic.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, RequestSpec};
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;
use era_solver::solvers::TaskSpec;

fn plain_bank() -> Arc<dyn ModelBank> {
    let sched = VpSchedule::default();
    Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))))
}

fn resident_bank() -> Arc<dyn ModelBank> {
    let sched = VpSchedule::default();
    Arc::new(
        MockBank::new(sched)
            .with("gmm8", Box::new(AnalyticGmm::gmm8(sched)))
            .with_residency(),
    )
}

fn spec(solver: &str, n: usize, nfe: usize, seed: u64) -> RequestSpec {
    RequestSpec {
        solver: solver.into(),
        n_samples: n,
        nfe,
        seed,
        ..Default::default()
    }
}

/// Run one spec on both banks and assert the samples agree bit-for-bit.
fn assert_paths_bitwise_equal(spec: RequestSpec) {
    let host = Coordinator::start(plain_bank(), CoordinatorConfig::default());
    let res_host = host.sample(spec.clone()).unwrap();
    host.shutdown();

    let dev = Coordinator::start(resident_bank(), CoordinatorConfig::default());
    let res_dev = dev.sample(spec.clone()).unwrap();
    let resident_converted = dev.telemetry().resident_lanes.load(Ordering::Relaxed);
    dev.shutdown();

    assert_eq!(res_host.nfe, res_dev.nfe, "nfe diverged for {}", spec.solver);
    assert_eq!(res_host.samples.rows(), res_dev.samples.rows());
    assert_eq!(res_host.samples.cols(), res_dev.samples.cols());
    for (i, (a, b)) in res_host
        .samples
        .as_slice()
        .iter()
        .zip(res_dev.samples.as_slice())
        .enumerate()
    {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "sample element {i} diverged for solver {} (host {a} vs resident {b})",
            spec.solver
        );
    }
    // The gauge must have unwound: every converted lane finished or
    // devolved before shutdown.
    assert_eq!(resident_converted, 0, "resident_lanes gauge leaked");
}

#[test]
fn ddim_resident_matches_host_bitwise() {
    assert_paths_bitwise_equal(spec("ddim", 32, 10, 7));
    assert_paths_bitwise_equal(spec("ddim", 5, 3, 99));
}

#[test]
fn era_resident_matches_host_bitwise() {
    // ERA exercises the full resident protocol: DDIM warmup advances,
    // Lagrange/Adams–Moulton combined advances, per-row eps distances
    // feeding the host-side error-robust selection, and the final-step
    // Finish (no trailing eval).
    assert_paths_bitwise_equal(spec("era", 32, 10, 1));
    assert_paths_bitwise_equal(spec("era", 17, 12, 5));
    assert_paths_bitwise_equal(spec("era", 8, 4, 1234));
}

#[test]
fn era_fixed_resident_matches_host_bitwise() {
    assert_paths_bitwise_equal(spec("era-fixed-5", 16, 10, 3));
}

#[test]
fn ineligible_workloads_fall_back_to_the_slab_path_bitwise() {
    // Stochastic churn and guided sampling never convert (residency
    // eligibility requires the plain deterministic workload); they must
    // run — and match the plain bank — through the fallback.
    let churned = RequestSpec {
        task: TaskSpec { churn: 0.3, ..Default::default() },
        ..spec("era", 16, 10, 21)
    };
    assert_paths_bitwise_equal(churned);
    let guided = RequestSpec {
        task: TaskSpec { guidance_scale: 2.0, guide_class: 1, ..Default::default() },
        ..spec("era", 8, 8, 2)
    };
    assert_paths_bitwise_equal(guided);
}

#[test]
fn resident_bytes_are_accounted_and_smaller_per_step_than_row_payloads() {
    // 10-step ERA at 64 rows: the slab path ships the 64×2 iterate and
    // its eps back every step; the resident path pays the upload once
    // plus O(coefficients) per step. Both counters must be non-zero,
    // and the resident run must move fewer bytes end to end.
    let n = 64;
    let host = Coordinator::start(plain_bank(), CoordinatorConfig::default());
    host.sample(spec("era", n, 10, 77)).unwrap();
    let host_bytes = host.telemetry().host_bytes_transferred.load(Ordering::Relaxed);
    host.shutdown();

    let dev = Coordinator::start(resident_bank(), CoordinatorConfig::default());
    dev.sample(spec("era", n, 10, 77)).unwrap();
    let dev_bytes = dev.telemetry().host_bytes_transferred.load(Ordering::Relaxed);
    dev.shutdown();

    assert!(host_bytes > 0, "slab path must account transfer bytes");
    assert!(dev_bytes > 0, "resident path must account transfer bytes");
    assert!(
        dev_bytes < host_bytes,
        "resident path moved {dev_bytes} bytes, slab path {host_bytes}"
    );
}

#[test]
fn cancel_of_an_idle_resident_lane_devolves_and_retires() {
    // min_rows far above the request's rows forces a linger after the
    // lane converts to residency; the cancel must gather the lane back
    // (devolve) and retire it during the wait — the classic
    // linger-cancel scenario, now crossing the residency boundary.
    let cfg = CoordinatorConfig {
        policy: BatchPolicy {
            max_rows: 256,
            min_rows: 4096,
            max_wait: Duration::from_secs(5),
        },
        ..Default::default()
    };
    let c = Coordinator::start(resident_bank(), cfg);
    let ticket = c.submit(spec("era", 8, 10, 13)).unwrap();
    let handle = ticket.cancel_handle();
    std::thread::sleep(Duration::from_millis(30));
    handle.cancel();
    let res = ticket.wait().unwrap();
    assert!(res.cancelled, "linger-cancel must retire the request early");
    assert_eq!(
        c.telemetry().resident_lanes.load(Ordering::Relaxed),
        0,
        "devolved lane must release the residency gauge"
    );
    c.shutdown();
}

#[test]
fn mixed_concurrent_traffic_matches_host_bitwise_per_request() {
    // Several concurrent requests with distinct seeds/NFEs: resident
    // lanes step alongside slab lanes in the same dispatch rounds, and
    // every request's samples must still match its solo host-path run.
    let specs: Vec<RequestSpec> = vec![
        spec("era", 16, 10, 101),
        spec("ddim", 16, 10, 102),
        spec("era", 8, 6, 103),
    ];
    let mut host_samples = Vec::new();
    for sp in &specs {
        let host = Coordinator::start(plain_bank(), CoordinatorConfig::default());
        host_samples.push(host.sample(sp.clone()).unwrap().samples);
        host.shutdown();
    }
    let dev = Coordinator::start(resident_bank(), CoordinatorConfig::default());
    let tickets: Vec<_> =
        specs.iter().map(|sp| dev.submit(sp.clone()).unwrap()).collect();
    for (ticket, want) in tickets.into_iter().zip(host_samples) {
        let got = ticket.wait().unwrap();
        assert_eq!(got.samples.rows(), want.rows());
        for (a, b) in want.as_slice().iter().zip(got.samples.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "concurrent resident run diverged");
        }
    }
    dev.shutdown();
}
