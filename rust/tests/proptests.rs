//! Property-based tests (hand-rolled generators over the [`Rng`]
//! substrate; the offline registry ships no proptest). Each property
//! runs a few hundred randomized cases with the failing seed printed so
//! a reproduction is one `Rng::new(seed)` away.

use std::sync::Arc;

use era_solver::coordinator::batcher::{Batcher, BatchPolicy};
use era_solver::json::{self, Json};
use era_solver::kernels::{PlanView, TrajectoryPlan};
use era_solver::linalg;
use era_solver::metrics::{self, Moments};
use era_solver::rng::Rng;
use era_solver::server::codec::{encode_frame, CodecError, Frame, FrameDecoder};
use era_solver::solvers::era::select_indices;
use era_solver::solvers::lagrange;
use era_solver::solvers::schedule::{make_grid, GridKind, VpSchedule};
use era_solver::solvers::{EvalRequest, TaskSpec, UNCOND};
use era_solver::tensor::Tensor;

const CASES: usize = 300;

#[test]
fn prop_lagrange_partition_of_unity() {
    // Interpolating a constant is exact for any distinct node set.
    let mut rng = Rng::new(0xA11CE);
    for case in 0..CASES {
        let k = 2 + (rng.below(5) as usize);
        let mut nodes: Vec<f64> = (0..k).map(|_| rng.uniform_in(1e-3, 1.0)).collect();
        nodes.sort_by(|a, b| b.partial_cmp(a).unwrap());
        nodes.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        if nodes.len() < 2 {
            continue;
        }
        let t = rng.uniform_in(-0.5, 1.5);
        let s: f64 = lagrange::weights(&nodes, t).iter().sum();
        assert!((s - 1.0).abs() < 1e-6, "case {case}: sum {s} nodes {nodes:?} t {t}");
    }
}

#[test]
fn prop_lagrange_exact_on_random_polynomials() {
    let mut rng = Rng::new(0xB0B);
    for case in 0..CASES {
        let k = 2 + (rng.below(4) as usize);
        // Well-separated nodes to keep the Vandermonde conditioned.
        let mut nodes = Vec::with_capacity(k);
        let mut t = rng.uniform_in(0.6, 1.0);
        for _ in 0..k {
            nodes.push(t);
            t -= rng.uniform_in(0.08, 0.25);
        }
        let coeffs: Vec<f64> = (0..k).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let poly = |x: f64| coeffs.iter().rev().fold(0.0, |acc, &c| acc * x + c);
        let vals: Vec<f64> = nodes.iter().map(|&n| poly(n)).collect();
        let probe = rng.uniform_in(-0.2, 1.2);
        let got = lagrange::interpolate_scalar(&nodes, &vals, probe);
        let want = poly(probe);
        assert!(
            (got - want).abs() < 1e-6 * (1.0 + want.abs()),
            "case {case}: {got} vs {want} (k={k})"
        );
    }
}

#[test]
fn prop_select_indices_invariants() {
    // Ascending, distinct, in range, anchored at the newest entry, for
    // random buffer lengths, orders and exponents (incl. extremes).
    let mut rng = Rng::new(0xCAFE);
    for case in 0..CASES * 3 {
        let i = 1 + (rng.below(200) as usize);
        let k = 2 + (rng.below(7) as usize);
        if k > i + 1 {
            continue;
        }
        let p = match rng.below(4) {
            0 => rng.uniform_in(1e-3, 1.0),
            1 => rng.uniform_in(1.0, 5.0),
            2 => rng.uniform_in(5.0, 100.0),
            _ => rng.uniform_in(0.0, 1e-3),
        };
        let idx = select_indices(i, k, p);
        assert_eq!(idx.len(), k, "case {case}: i={i} k={k} p={p}");
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "case {case}: not ascending {idx:?}");
        assert_eq!(*idx.last().unwrap(), i, "case {case}: anchor missing {idx:?}");
    }
}

#[test]
fn prop_select_indices_monotone_in_exponent() {
    // Higher measured error (bigger exponent) never selects a *later*
    // earliest-base than lower error: the selection leans earlier.
    let mut rng = Rng::new(0xD1CE);
    for case in 0..CASES {
        let i = 6 + (rng.below(100) as usize);
        let k = 3 + (rng.below(3) as usize);
        let p_lo = rng.uniform_in(0.2, 2.0);
        let p_hi = p_lo + rng.uniform_in(0.1, 5.0);
        let lo = select_indices(i, k, p_lo);
        let hi = select_indices(i, k, p_hi);
        assert!(
            hi[0] <= lo[0],
            "case {case}: i={i} k={k} p {p_lo}->{p_hi}: {lo:?} -> {hi:?}"
        );
    }
}

#[test]
fn prop_batcher_conserves_and_routes_rows() {
    // Random request mixes: every row comes back to its source exactly
    // once, in order, with the identity model.
    let mut rng = Rng::new(0xBA7C);
    for case in 0..CASES {
        let n_req = 1 + (rng.below(8) as usize);
        let dim = 1 + (rng.below(4) as usize);
        let max_rows = 1 + (rng.below(64) as usize);
        let reqs: Vec<EvalRequest> = (0..n_req)
            .map(|_| {
                let rows = 1 + (rng.below(80) as usize);
                EvalRequest {
                    x: Arc::new(rng.normal_tensor(rows, dim)),
                    t: rng.uniform_in(1e-3, 1.0),
                    cond: None,
                }
            })
            .collect();
        let pending: Vec<(usize, &EvalRequest)> = reqs.iter().enumerate().collect();
        let batcher = Batcher::new(BatchPolicy {
            max_rows,
            ..Default::default()
        });
        let plan = batcher.pack(&pending);
        assert_eq!(
            plan.rows,
            reqs.iter().map(|r| r.x.rows()).sum::<usize>(),
            "case {case}: rows lost"
        );
        let mut reassembled: Vec<Vec<f32>> = vec![Vec::new(); n_req];
        for slab in &plan.slabs {
            assert!(slab.rows() <= max_rows, "case {case}: slab too big");
            // Per-row times must match the owning request.
            for seg in &slab.segments {
                for r in seg.start..seg.start + seg.rows {
                    assert!(
                        (slab.t[r] as f64 - reqs[seg.source].t).abs() < 1e-6,
                        "case {case}: time routed wrong"
                    );
                }
            }
            for (src, part) in Batcher::unpack(slab, slab.x()) {
                reassembled[src].extend_from_slice(part.as_slice());
            }
        }
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(
                reassembled[i],
                req.x.as_slice(),
                "case {case}: request {i} content mangled"
            );
        }
    }
}

#[test]
fn prop_task_workload_resolution_injective() {
    // (task kind, strength bucket, guidance) -> (suffix start, paired
    // rows) must be injective: suffix views never alias the full plan
    // (or each other), and guided workloads never collapse onto
    // unguided ones in admission accounting.
    let mut rng = Rng::new(0x7A5C);
    let sched = VpSchedule::default();
    for case in 0..60 {
        let steps = 4 + (rng.below(28) as usize);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let plan = Arc::new(TrajectoryPlan::new(sched, grid));

        // Exact buckets are injective: strength 1 - j/steps <-> start j.
        let mut seen_starts = vec![false; steps + 1];
        for j in 0..=steps {
            let t = TaskSpec {
                strength: 1.0 - j as f64 / steps as f64,
                ..Default::default()
            };
            let start = t.suffix_start(steps);
            assert_eq!(start, j, "case {case}: bucket {j} of {steps}");
            assert!(!seen_starts[start], "case {case}: bucket collision at {start}");
            seen_starts[start] = true;
            // Interior suffix views never alias the full plan: same
            // remaining-step count only at j = 0, and the first visible
            // transition of an interior view is a *different* transition.
            if (1..steps).contains(&j) {
                let v = PlanView::suffix(plan.clone(), start);
                assert_eq!(v.steps(), steps - j);
                assert_eq!(v.t(0), plan.t(start));
                assert_ne!(
                    v.ddim_coeffs(0),
                    plan.ddim_coeffs(0),
                    "case {case}: suffix {j} aliases the full plan's first transition"
                );
            }
        }

        // Arbitrary continuous strengths still land in [0, steps] and
        // are monotone (higher strength never starts later).
        let s1 = rng.uniform_in(0.0, 1.0);
        let s2 = rng.uniform_in(0.0, 1.0);
        let (lo, hi) = if s1 <= s2 { (s1, s2) } else { (s2, s1) };
        let t_lo = TaskSpec { strength: lo, ..Default::default() };
        let t_hi = TaskSpec { strength: hi, ..Default::default() };
        assert!(
            t_hi.suffix_start(steps) <= t_lo.suffix_start(steps),
            "case {case}: start must not increase with strength"
        );

        // Guidance discriminates workloads in row accounting whatever
        // the scale, and scale 0 collapses to the plain task.
        let g = TaskSpec {
            guidance_scale: rng.uniform_in(0.1, 8.0),
            ..Default::default()
        };
        assert_eq!(g.rows_per_sample(), 2);
        assert_ne!(g.label(), TaskSpec::default().label());
        let g0 = TaskSpec { guidance_scale: 0.0, ..Default::default() };
        assert_eq!(g0.rows_per_sample(), 1);
        assert_eq!(g0.label(), "uncond");
    }
}

#[test]
fn prop_paired_rows_survive_slab_mixing() {
    // Guided requests contribute paired cond/uncond rows. Property: for
    // arbitrary mixes of paired and plain requests and arbitrary slab
    // caps, the gather/scatter round trip returns every request's rows
    // in order with its conditioning channel intact — so row i and row
    // i + pairs of a guided request stay a cond/uncond pair no matter
    // how slabs split them.
    let mut rng = Rng::new(0x9A12);
    for case in 0..CASES {
        let n_req = 1 + (rng.below(6) as usize);
        let dim = 1 + (rng.below(3) as usize);
        let max_rows = 1 + (rng.below(48) as usize);
        let mut reqs: Vec<EvalRequest> = Vec::new();
        let mut conds: Vec<Option<Vec<f32>>> = Vec::new();
        for _ in 0..n_req {
            if rng.below(2) == 0 {
                // Guided-style: pairs rows, first half carries a class.
                let pairs = 1 + (rng.below(20) as usize);
                let class = rng.below(8) as f32;
                let mut cond = vec![class; pairs];
                cond.resize(pairs * 2, UNCOND);
                reqs.push(EvalRequest {
                    x: Arc::new(rng.normal_tensor(pairs * 2, dim)),
                    t: rng.uniform_in(1e-3, 1.0),
                    cond: Some(Arc::new(cond.clone())),
                });
                conds.push(Some(cond));
            } else {
                let rows = 1 + (rng.below(40) as usize);
                reqs.push(EvalRequest {
                    x: Arc::new(rng.normal_tensor(rows, dim)),
                    t: rng.uniform_in(1e-3, 1.0),
                    cond: None,
                });
                conds.push(None);
            }
        }
        let pending: Vec<(usize, &EvalRequest)> = reqs.iter().enumerate().collect();
        let batcher = Batcher::new(BatchPolicy { max_rows, ..Default::default() });
        let plan = batcher.pack(&pending);

        let mut rows_back: Vec<Vec<f32>> = vec![Vec::new(); n_req];
        let mut cond_back: Vec<Vec<f32>> = vec![Vec::new(); n_req];
        for slab in &plan.slabs {
            assert_eq!(slab.c().len(), slab.t.len(), "case {case}: channel length");
            for seg in &slab.segments {
                cond_back[seg.source].extend_from_slice(&slab.c()[seg.start..seg.start + seg.rows]);
            }
            for (src, part) in Batcher::unpack(slab, slab.x()) {
                rows_back[src].extend_from_slice(part.as_slice());
            }
        }
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(rows_back[i], req.x.as_slice(), "case {case}: rows of req {i}");
            match &conds[i] {
                Some(c) => {
                    assert_eq!(&cond_back[i], c, "case {case}: cond channel of req {i}");
                    // Pairing intact: first half classes, second half
                    // UNCOND, in the original row order.
                    let pairs = c.len() / 2;
                    assert!(cond_back[i][..pairs].iter().all(|&v| v >= 0.0));
                    assert!(cond_back[i][pairs..].iter().all(|&v| v < 0.0));
                }
                None => {
                    assert!(
                        cond_back[i].iter().all(|&v| v == UNCOND),
                        "case {case}: plain req {i} grew conditioning"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_plan_lagrange_concurrent_lookups_deterministic() {
    // The shared TrajectoryPlan's Lagrange memo is read and populated
    // concurrently by every request on a configuration. Property: for a
    // random pool of (target, indices) queries, N threads racing on one
    // plan all observe exactly the weights a single thread computes.
    let mut rng = Rng::new(0x9_1A9);
    for case in 0..20 {
        let sched = VpSchedule::default();
        let steps = 8 + (rng.below(24) as usize);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let plan = Arc::new(TrajectoryPlan::new(sched, grid.clone()));

        // Random query pool (ascending distinct indices, valid targets).
        let mut queries: Vec<(usize, Vec<usize>)> = Vec::new();
        for _ in 0..24 {
            let k = 2 + (rng.below(4) as usize);
            let mut idx: Vec<usize> = (0..k)
                .map(|_| rng.below((grid.len() - 1) as u64) as usize)
                .collect();
            idx.sort_unstable();
            idx.dedup();
            if idx.len() < 2 {
                continue;
            }
            let target = grid.len() - 1;
            queries.push((target, idx));
        }

        // Ground truth, single-threaded on a fresh plan.
        let reference = Arc::new(TrajectoryPlan::new(sched, grid));
        let want: Vec<Vec<f64>> = queries
            .iter()
            .map(|(t, idx)| reference.lagrange_weights(*t, idx).as_ref().clone())
            .collect();

        let handles: Vec<_> = (0..4)
            .map(|_| {
                let plan = plan.clone();
                let queries = queries.clone();
                std::thread::spawn(move || {
                    queries
                        .iter()
                        .map(|(t, idx)| plan.lagrange_weights(*t, idx).as_ref().clone())
                        .collect::<Vec<Vec<f64>>>()
                })
            })
            .collect();
        for h in handles {
            let got = h.join().expect("lookup thread panicked");
            assert_eq!(got, want, "case {case}: concurrent lookup diverged");
        }
        // Memo coherence: every distinct query was built at most once
        // per (target, indices) key... racing builders may double-build,
        // but lookups after the race must all hit.
        let before = plan.lagrange_hits();
        for (t, idx) in &queries {
            let _ = plan.lagrange_weights(*t, idx);
        }
        assert_eq!(
            plan.lagrange_hits() - before,
            queries.len(),
            "case {case}: settled memo must serve every query from cache"
        );
    }
}

#[test]
fn prop_kernel_weighted_sum_matches_unfused() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let rows = 1 + (rng.below(32) as usize);
        let cols = 1 + (rng.below(16) as usize);
        let k = rng.below(6) as usize;
        let x = rng.normal_tensor(rows, cols);
        let eps: Vec<Tensor> = (0..k).map(|_| rng.normal_tensor(rows, cols)).collect();
        let refs: Vec<&Tensor> = eps.iter().collect();
        let w: Vec<f64> = (0..k).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let (a, b) = (rng.uniform_in(-1.5, 1.5), rng.uniform_in(-1.5, 1.5));
        let fused = Tensor::kernel_weighted_sum(&x, a as f32, b as f32, &refs, &w);
        let mut want = if k == 0 {
            Tensor::zeros(rows, cols)
        } else {
            Tensor::weighted_sum(&refs, &w)
        };
        want.scale(b as f32);
        want.axpy(a as f32, &x);
        for (f, u) in fused.as_slice().iter().zip(want.as_slice()) {
            assert!((f - u).abs() < 1e-4, "case {case}: {f} vs {u}");
        }
    }
}

#[test]
fn prop_grids_decrease_and_hit_endpoints() {
    let mut rng = Rng::new(0x6121D);
    let sched = VpSchedule::default();
    for case in 0..CASES {
        let n = 1 + (rng.below(120) as usize);
        let t_end = rng.uniform_in(1e-5, 0.05);
        let kind = match rng.below(3) {
            0 => GridKind::Uniform,
            1 => GridKind::Quadratic,
            _ => GridKind::LogSnr,
        };
        let g = make_grid(&sched, kind, n, 1.0, t_end);
        assert_eq!(g.len(), n + 1, "case {case}");
        assert_eq!(g[0], 1.0);
        assert_eq!(g[n], t_end);
        assert!(g.windows(2).all(|w| w[1] < w[0]), "case {case}: {kind:?} not decreasing");
    }
}

#[test]
fn prop_json_roundtrip() {
    // to_string -> parse is the identity on random JSON trees.
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.uniform_in(-1e6, 1e6) * 1e3).round() / 1e3),
            3 => {
                let len = rng.below(8) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    let mut rng = Rng::new(0x150);
    for case in 0..CASES {
        let j = gen(&mut rng, 3);
        let text = j.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e:?} on {text}"));
        assert_eq!(back, j, "case {case}: {text}");
    }
}

#[test]
fn prop_sqrtm_squares_back() {
    // sqrtm(A)^2 ~ A on random PSD matrices (the FID substrate).
    let mut rng = Rng::new(0x5157);
    for case in 0..100 {
        let d = 2 + (rng.below(6) as usize);
        // A = B B^T + eps I is PSD.
        let b: Vec<f64> = (0..d * d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let mut a = vec![0.0f64; d * d];
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += b[i * d + k] * b[j * d + k];
                }
                a[i * d + j] = s + if i == j { 1e-6 } else { 0.0 };
            }
        }
        let r = linalg::sqrtm_psd(&a, d);
        let r2 = linalg::matmul(&r, &r, d);
        let scale: f64 = a.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
        for (x, y) in r2.iter().zip(a.iter()) {
            assert!(
                (x - y).abs() < 1e-6 * scale,
                "case {case}: sqrtm^2 deviates {x} vs {y} (d={d})"
            );
        }
    }
}

#[test]
fn prop_fid_zero_on_self_and_positive_on_shift() {
    let mut rng = Rng::new(0xF1D);
    for case in 0..60 {
        let d = 2 + (rng.below(4) as usize);
        let n = 200 + rng.below(200) as usize;
        let x = rng.normal_tensor(n, d);
        let m = Moments::from_tensor(&x);
        let self_fid = metrics::fid(&x, &m);
        assert!(self_fid.abs() < 1e-4, "case {case}: FID(X,X) = {self_fid}");

        // Shift one coordinate: FID must increase roughly like the
        // squared mean displacement.
        let mut y = x.clone();
        for r in 0..y.rows() {
            y.row_mut(r)[0] += 2.0;
        }
        let shifted = metrics::fid(&y, &m);
        assert!(shifted > 3.0, "case {case}: shifted FID {shifted}");
    }
}

#[test]
fn prop_frechet_symmetric_nonnegative() {
    let mut rng = Rng::new(0x5F3);
    for case in 0..60 {
        let d = 2 + (rng.below(3) as usize);
        let a = Moments::from_tensor(&rng.normal_tensor(150, d));
        let b = Moments::from_tensor(&rng.normal_tensor(150, d));
        let ab = metrics::frechet_distance(&a, &b);
        let ba = metrics::frechet_distance(&b, &a);
        assert!(ab >= -1e-8, "case {case}: negative distance {ab}");
        assert!((ab - ba).abs() < 1e-6 * (1.0 + ab.abs()), "case {case}: {ab} vs {ba}");
    }
}

#[test]
fn prop_rng_streams_do_not_collide() {
    // Distinct streams from one seed must decorrelate (the coordinator
    // seeds each request chunk independently).
    let mut a = Rng::for_stream(7, 1);
    let mut b = Rng::for_stream(7, 2);
    let mut same = 0;
    for _ in 0..1000 {
        if a.next_u64() == b.next_u64() {
            same += 1;
        }
    }
    assert_eq!(same, 0);
}

#[test]
fn prop_slab_completion_order_immaterial() {
    // The pipelined scheduler routes slab completions as they arrive,
    // in whatever order the executors finish. For an arbitrary pack
    // plan and an arbitrary permutation of slab completions, every
    // request's reassembled eps must be bitwise identical to the
    // in-order result — guaranteed by the absolute `src_start` offset
    // each segment carries.
    fn pseudo_eval(x: &Tensor, t: &[f32], c: &[f32]) -> Tensor {
        let cols = x.cols();
        let v: Vec<f32> = x
            .as_slice()
            .iter()
            .enumerate()
            .map(|(i, &val)| val * 1.5 + t[i / cols] + c[i / cols])
            .collect();
        Tensor::from_vec(v, x.rows(), cols)
    }
    let mut rng = Rng::new(0x5AB0);
    for case in 0..CASES {
        let n_req = 1 + (rng.below(6) as usize);
        let dim = 1 + (rng.below(3) as usize);
        let max_rows = 1 + (rng.below(24) as usize);
        let reqs: Vec<EvalRequest> = (0..n_req)
            .map(|_| {
                let rows = 1 + (rng.below(40) as usize);
                let cond = if rng.below(2) == 0 {
                    Some(Arc::new(
                        (0..rows)
                            .map(|_| if rng.below(3) == 0 { UNCOND } else { rng.below(8) as f32 })
                            .collect::<Vec<f32>>(),
                    ))
                } else {
                    None
                };
                EvalRequest {
                    x: Arc::new(rng.normal_tensor(rows, dim)),
                    t: rng.uniform_in(1e-3, 1.0),
                    cond,
                }
            })
            .collect();
        let pending: Vec<(usize, &EvalRequest)> = reqs.iter().enumerate().collect();
        let batcher = Batcher::new(BatchPolicy { max_rows, ..Default::default() });
        let plan = batcher.pack(&pending);

        // "Run" every slab through a deterministic per-row pseudo-model.
        let outs: Vec<Tensor> =
            plan.slabs.iter().map(|s| pseudo_eval(s.x(), &s.t, s.c())).collect();

        // Reassemble exactly the way the scheduler scatters completions.
        let assemble = |order: &[usize]| -> Vec<Tensor> {
            let mut bufs: Vec<Tensor> =
                reqs.iter().map(|r| Tensor::zeros(r.x.rows(), r.x.cols())).collect();
            for &si in order {
                for seg in &plan.slabs[si].segments {
                    era_solver::kernels::fused::scatter_rows(
                        &mut bufs[seg.source],
                        seg.src_start,
                        &outs[si],
                        seg.start,
                        seg.rows,
                    );
                }
            }
            bufs
        };
        let in_order: Vec<usize> = (0..plan.slabs.len()).collect();
        let mut perm = in_order.clone();
        for i in (1..perm.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            perm.swap(i, j);
        }
        let a = assemble(&in_order);
        let b = assemble(&perm);
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(
                a[i].as_slice(),
                b[i].as_slice(),
                "case {case}: request {i} differs under completion order {perm:?}"
            );
            // Both must equal evaluating the request alone — stitching
            // reconstructs the full eps exactly once per row.
            let t_vec = vec![req.t as f32; req.x.rows()];
            let c_vec = match &req.cond {
                Some(c) => c.as_ref().clone(),
                None => vec![UNCOND; req.x.rows()],
            };
            let want = pseudo_eval(&req.x, &t_vec, &c_vec);
            assert_eq!(
                a[i].as_slice(),
                want.as_slice(),
                "case {case}: request {i} reassembly diverged from direct eval"
            );
        }
    }
}

#[test]
fn prop_concurrent_recording_keeps_span_boundaries_ordered() {
    // Scheduler + executor threads record into one shared flight
    // recorder. Per trace, the externally enforced happens-before edges
    // (admission before any mid-lifecycle event, finalize after all of
    // them) must survive the interleaving: the snapshot shows exactly
    // one admission first, exactly one terminal event last, and
    // timestamps nondecreasing throughout.
    use era_solver::obs::{FlightRecorder, SpanKind};
    use std::sync::mpsc;

    let mut rng = Rng::new(0x0B5E);
    for case in 0..24usize {
        let rec = Arc::new(FlightRecorder::with_capacity(2048));
        let traces: Vec<u64> = (0..4).map(|i| (case * 10 + i + 1) as u64).collect();
        let mut handles = Vec::new();
        for &t in &traces {
            let rec_s = rec.clone();
            let rec_e = rec.clone();
            let n_mid = 1 + rng.below(40) as u32;
            let (tx_go, rx_go) = mpsc::channel::<u32>();
            let (tx_done, rx_done) = mpsc::channel::<()>();
            // Executor: waits for admission, then races the scheduler's
            // own solver-step writes for this trace.
            handles.push(std::thread::spawn(move || {
                let n = rx_go.recv().unwrap();
                for s in 0..n {
                    rec_e.record(
                        t,
                        SpanKind::SlabComplete {
                            seq: s as u64,
                            round: s as u64,
                            executor: 1,
                            eval_nanos: 5,
                        },
                    );
                }
                tx_done.send(()).unwrap();
            }));
            handles.push(std::thread::spawn(move || {
                rec_s.record(t, SpanKind::Admitted { rows: 8 });
                rec_s.record(t, SpanKind::LaneAttach { lane: 0 });
                tx_go.send(n_mid).unwrap();
                for s in 0..n_mid {
                    rec_s.record(t, SpanKind::SolverStep { lane: 0, step: s });
                }
                rx_done.recv().unwrap();
                rec_s.record(t, SpanKind::Finalize { nfe: n_mid });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for &t in &traces {
            let events = rec.snapshot_trace(t);
            assert!(events.len() >= 4, "case {case}: trace {t} too short");
            assert_eq!(events.first().unwrap().kind.name(), "admitted", "case {case} trace {t}");
            assert_eq!(events.last().unwrap().kind.name(), "finalize", "case {case} trace {t}");
            assert_eq!(
                events.iter().filter(|e| e.kind.name() == "admitted").count(),
                1,
                "case {case} trace {t}: duplicate admission"
            );
            assert_eq!(
                events.iter().filter(|e| e.kind.is_terminal()).count(),
                1,
                "case {case} trace {t}: duplicate terminal"
            );
            assert!(
                events.windows(2).all(|w| w[0].at_nanos <= w[1].at_nanos),
                "case {case} trace {t}: timestamps regressed"
            );
        }
    }
}

/// Random frame payload: printable bytes only, so no accidental `\n`
/// and no trailing `\r` for the decoder to strip.
fn random_frame_line(rng: &mut Rng) -> String {
    const PALETTE: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ\
                             0123456789{}\":,.[]-+_ \t";
    let len = rng.below(40) as usize;
    (0..len).map(|_| PALETTE[rng.below(PALETTE.len() as u64) as usize] as char).collect()
}

#[test]
fn prop_codec_reassembles_frames_under_arbitrary_splits() {
    // Any sequence of frames, serialized (mixing `\n` and `\r\n`
    // terminators) and fed to the decoder in arbitrary chunks — byte at
    // a time, random splits, or all at once — reassembles to exactly
    // the original frame sequence.
    let mut rng = Rng::new(0xC0DEC);
    for case in 0..CASES {
        let n_frames = 1 + rng.below(8) as usize;
        let want: Vec<String> = (0..n_frames).map(|_| random_frame_line(&mut rng)).collect();
        let mut bytes = Vec::new();
        for line in &want {
            bytes.extend_from_slice(line.as_bytes());
            bytes.extend_from_slice(if rng.below(2) == 0 { b"\n" } else { b"\r\n" });
        }
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        let mut at = 0;
        while at < bytes.len() {
            let chunk = match rng.below(3) {
                0 => 1,
                1 => 1 + rng.below(7) as usize,
                _ => bytes.len() - at,
            };
            let end = (at + chunk).min(bytes.len());
            d.push(&bytes[at..end]);
            at = end;
            while let Some(f) = d.next_frame().expect("printable frames never overflow") {
                got.push(f);
            }
        }
        assert_eq!(got, want, "case {case}");
        assert_eq!(d.buffered(), 0, "case {case}: bytes left over");
    }
}

#[test]
fn prop_codec_truncated_frame_is_need_more_never_partial() {
    // An unterminated frame is `Ok(None)` at every prefix (never a
    // partial frame, never an error while under the cap); the newline
    // then delivers it whole.
    let mut rng = Rng::new(0x7EED5);
    for case in 0..CASES {
        let line = random_frame_line(&mut rng);
        let mut d = FrameDecoder::new();
        let mut at = 0;
        while at < line.len() {
            let end = (at + 1 + rng.below(5) as usize).min(line.len());
            d.push(&line.as_bytes()[at..end]);
            at = end;
            assert_eq!(d.next_frame(), Ok(None), "case {case}: partial at byte {at}");
        }
        d.push(b"\n");
        assert_eq!(d.next_frame(), Ok(Some(line)), "case {case}");
        assert_eq!(d.next_frame(), Ok(None), "case {case}: trailing frame");
    }
}

#[test]
fn prop_codec_oversized_line_errors_deterministically() {
    // A line that outgrows the cap without a newline is a deterministic
    // `Oversized` error naming the cap, and the decoder stays errored
    // as more bytes arrive (the connection cannot resync).
    let mut rng = Rng::new(0xB16);
    for case in 0..CASES {
        let cap = 1 + rng.below(64) as usize;
        let mut d = FrameDecoder::with_cap(cap);
        let mut pushed = 0usize;
        let mut first_err: Option<CodecError> = None;
        while pushed <= cap + 32 {
            let chunk = 1 + rng.below(16) as usize;
            d.push(&vec![b'x'; chunk]);
            pushed += chunk;
            match d.next_frame() {
                Ok(None) => {
                    assert!(pushed <= cap, "case {case}: {pushed} buffered over cap {cap}")
                }
                Ok(Some(f)) => panic!("case {case}: phantom frame {f:?}"),
                Err(e) => {
                    assert!(pushed > cap, "case {case}: early error {e} at {pushed}/{cap}");
                    let CodecError::Oversized { len, cap: seen } = &e;
                    assert_eq!((*len, *seen), (pushed, cap), "case {case}");
                    first_err.get_or_insert(e);
                }
            }
        }
        assert!(first_err.is_some(), "case {case}: cap {cap} never tripped");
        // Still errored on a call with no new bytes.
        assert!(d.next_frame().is_err(), "case {case}: error not sticky");
    }
}

#[test]
fn prop_codec_never_panics_on_binary_garbage() {
    // Arbitrary binary input (embedded newlines, invalid UTF-8, NULs)
    // never panics: every frame comes back as a lossily-decoded string
    // and re-encoding conserves the frame count.
    let mut rng = Rng::new(0x6A4BA6E);
    for case in 0..CASES {
        let len = rng.below(512) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let newlines = bytes.iter().filter(|&&b| b == b'\n').count();
        let mut d = FrameDecoder::new();
        let mut frames = 0usize;
        let mut at = 0;
        while at < bytes.len() {
            let end = (at + 1 + rng.below(32) as usize).min(bytes.len());
            d.push(&bytes[at..end]);
            at = end;
            while let Some(f) = d.next_frame().expect("under default cap") {
                let mut re = Vec::new();
                encode_frame(&f, &mut re);
                assert_eq!(re.last(), Some(&b'\n'));
                frames += 1;
            }
        }
        assert_eq!(frames, newlines, "case {case}: frame count vs newline count");
    }
}

#[test]
fn prop_codec_counted_payloads_reassemble_under_arbitrary_splits() {
    // A mixed script of text lines and announced binary payloads
    // (arbitrary bytes — embedded `\n`, NULs, invalid UTF-8) survives
    // any chunking: after a header line of the form `P<len>` the test
    // arms counted mode, the payload comes back byte-exact in one
    // frame, and the decoder drops back to line scanning afterwards.
    let mut rng = Rng::new(0xB1A0B);
    for case in 0..CASES {
        let n_items = 1 + rng.below(6) as usize;
        let mut want: Vec<Frame> = Vec::new();
        let mut bytes = Vec::new();
        for _ in 0..n_items {
            if rng.below(2) == 0 {
                let line = random_frame_line(&mut rng);
                bytes.extend_from_slice(line.as_bytes());
                bytes.push(b'\n');
                want.push(Frame::Line(line));
            } else {
                let len = rng.below(96) as usize;
                let payload: Vec<u8> =
                    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
                let header = format!("P{len}");
                bytes.extend_from_slice(header.as_bytes());
                bytes.push(b'\n');
                bytes.extend_from_slice(&payload);
                want.push(Frame::Line(header));
                want.push(Frame::Payload(payload));
            }
        }
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        let mut at = 0;
        while at < bytes.len() {
            let chunk = match rng.below(3) {
                0 => 1,
                1 => 1 + rng.below(9) as usize,
                _ => bytes.len() - at,
            };
            let end = (at + chunk).min(bytes.len());
            d.push(&bytes[at..end]);
            at = end;
            while let Some(f) = d.next_any().expect("script stays under the cap") {
                if let Frame::Line(l) = &f {
                    if let Some(n) = l.strip_prefix('P').and_then(|n| n.parse::<usize>().ok()) {
                        d.expect_payload(n).expect("announced length is under the cap");
                    }
                }
                got.push(f);
            }
        }
        assert_eq!(got, want, "case {case}");
        assert_eq!(d.buffered(), 0, "case {case}: bytes left over");
        assert!(!d.awaiting_payload(), "case {case}: counted mode leaked");
    }
}

#[test]
fn prop_codec_truncated_payload_is_need_more_never_partial() {
    // An announced payload is `Ok(None)` at every strict prefix — never
    // a short frame — and the final byte delivers it whole, leaving the
    // decoder back in line mode.
    let mut rng = Rng::new(0x7A710AD);
    for case in 0..CASES {
        let len = 1 + rng.below(128) as usize;
        let payload: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let mut d = FrameDecoder::new();
        d.expect_payload(len).unwrap();
        assert!(d.awaiting_payload(), "case {case}");
        let mut at = 0;
        while at < len {
            let end = (at + 1 + rng.below(7) as usize).min(len);
            if end < len {
                d.push(&payload[at..end]);
                assert_eq!(d.next_any(), Ok(None), "case {case}: partial at byte {end}");
            } else {
                d.push(&payload[at..end]);
            }
            at = end;
        }
        assert_eq!(d.next_any(), Ok(Some(Frame::Payload(payload))), "case {case}");
        assert!(!d.awaiting_payload(), "case {case}: counted mode leaked");
        assert_eq!(d.next_any(), Ok(None), "case {case}: trailing frame");
    }
}

#[test]
fn prop_codec_oversized_payload_announce_is_sticky_until_reset() {
    // Announcing a payload above the cap errors immediately, the error
    // repeats on every later call no matter what bytes arrive (the
    // stream cannot resync past an unframed blob), and only `reset`
    // returns the decoder to service.
    let mut rng = Rng::new(0x51C4B);
    for case in 0..CASES {
        let cap = 1 + rng.below(64) as usize;
        let announced = cap + 1 + rng.below(64) as usize;
        let mut d = FrameDecoder::with_cap(cap);
        let Err(CodecError::Oversized { len, cap: seen }) = d.expect_payload(announced) else {
            panic!("case {case}: over-cap announce accepted");
        };
        assert_eq!((len, seen), (announced, cap), "case {case}");
        for _ in 0..3 {
            d.push(&vec![b'x'; 1 + rng.below(16) as usize]);
            assert!(d.next_any().is_err(), "case {case}: error not sticky");
        }
        d.reset();
        d.push(b"ok\n");
        assert_eq!(
            d.next_any(),
            Ok(Some(Frame::Line("ok".into()))),
            "case {case}: reset did not clear the failure"
        );
    }
}
