//! Golden-trajectory equivalence: the kernel/plan refactor changes
//! performance, never numerics.
//!
//! Each solver kind is driven twice from the same prior noise:
//! * the **production path** — `SolverKind::build` (plan-backed,
//!   in-place kernels, Arc'd iterate, ring-buffer history);
//! * a **reference driver** below that restates the pre-refactor step
//!   math verbatim: per-step `sched.*` coefficient evaluation,
//!   allocating `Tensor::affine`/`weighted_sum`/`lagrange::interpolate`
//!   combinations, per-step `rng.normal_tensor` noise.
//!
//! The two must agree within 1e-6 elementwise (they are bit-identical
//! in practice — the kernels replicate the accumulation order — but the
//! contract is 1e-6). A drift here means the refactor changed the
//! solver, not just its cost.

use era_solver::rng::Rng;
use era_solver::solvers::adams_explicit::AB4;
use era_solver::solvers::adams_implicit::am_weights;
use era_solver::solvers::dpm::{fast_order_schedule, fixed_order_schedule};
use era_solver::solvers::era::{select_indices, Selection};
use era_solver::solvers::eps_model::{AnalyticGmm, EpsModel, NoisyEps, UNCOND};
use era_solver::solvers::lagrange;
use era_solver::solvers::schedule::{make_grid, GridKind, VpSchedule};
use era_solver::solvers::{sample_with, SolverKind, TaskSpec};
use era_solver::tensor::Tensor;

fn eval(model: &dyn EpsModel, x: &Tensor, t: f64) -> Tensor {
    model.eval(x, &vec![t as f32; x.rows()])
}

/// DDIM transfer (Eq. 8), allocating, straight off the schedule.
fn phi(sched: &VpSchedule, x: &Tensor, eps: &Tensor, t_from: f64, t_to: f64) -> Tensor {
    let (a, b) = sched.ddim_coeffs(t_from, t_to);
    x.affine(a as f32, b as f32, eps)
}

fn ref_ddim(sched: &VpSchedule, grid: &[f64], mut x: Tensor, model: &dyn EpsModel) -> Tensor {
    for i in 0..grid.len() - 1 {
        let eps = eval(model, &x, grid[i]);
        x = phi(sched, &x, &eps, grid[i], grid[i + 1]);
    }
    x
}

fn ref_ddpm(
    sched: &VpSchedule,
    grid: &[f64],
    mut x: Tensor,
    model: &dyn EpsModel,
    seed: u64,
) -> Tensor {
    let mut rng = Rng::for_stream(seed, 0xD0);
    for i in 0..grid.len() - 1 {
        let eps = eval(model, &x, grid[i]);
        let ab_cur = sched.alpha_bar(grid[i]);
        let ab_next = sched.alpha_bar(grid[i + 1]);
        let alpha = ab_cur / ab_next;
        let coef = ((1.0 - alpha) / (1.0 - ab_cur).sqrt()) as f32;
        x.axpy(-coef, &eps);
        x.scale((1.0 / alpha.sqrt()) as f32);
        let last = i + 2 == grid.len();
        if !last {
            let var = (1.0 - ab_next) / (1.0 - ab_cur) * (1.0 - alpha);
            if var > 0.0 {
                let z = rng.normal_tensor(x.rows(), x.cols());
                x.axpy(var.sqrt() as f32, &z);
            }
        }
    }
    x
}

fn ref_iadams(sched: &VpSchedule, grid: &[f64], mut x: Tensor, model: &dyn EpsModel) -> Tensor {
    let mut hist: Vec<Tensor> = Vec::new(); // newest first
    for i in 0..grid.len() - 1 {
        let (t_cur, t_next) = (grid[i], grid[i + 1]);
        if hist.is_empty() {
            let eps = eval(model, &x, t_cur);
            x = phi(sched, &x, &eps, t_cur, t_next);
            hist.insert(0, eps);
            continue;
        }
        // AB predictor (order ramps with fill level).
        let refs: Vec<&Tensor> = hist.iter().collect();
        let eps_p = match hist.len() {
            1 => refs[0].clone(),
            2 => Tensor::weighted_sum(&refs[..2], &[1.5, -0.5]),
            3 => Tensor::weighted_sum(&refs[..3], &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0]),
            _ => Tensor::weighted_sum(&refs[..4], &AB4),
        };
        let x_pred = phi(sched, &x, &eps_p, t_cur, t_next);
        let eps_new = eval(model, &x_pred, t_next);
        // AM corrector with the predicted-point eval in the implicit slot.
        let order = (hist.len() + 1).min(4);
        let w = am_weights(order);
        let mut tensors: Vec<&Tensor> = vec![&eps_new];
        tensors.extend(hist.iter().take(order - 1));
        let eps_c = Tensor::weighted_sum(&tensors, w);
        x = phi(sched, &x, &eps_c, t_cur, t_next);
        hist.insert(0, eps_new);
        hist.truncate(4);
    }
    x
}

fn ref_era(
    sched: &VpSchedule,
    grid: &[f64],
    x: Tensor,
    model: &dyn EpsModel,
    k: usize,
    selection: &Selection,
) -> Tensor {
    ref_era_churn(sched, grid, x, model, k, selection, 0.0, 0)
}

/// ERA reference with optional SDE churn: after every interior
/// transition, add `churn * sqrt(var_ddpm)`-scaled Gaussian noise from
/// the dedicated per-request stream — verbatim the production rule.
#[allow(clippy::too_many_arguments)]
fn ref_era_churn(
    sched: &VpSchedule,
    grid: &[f64],
    mut x: Tensor,
    model: &dyn EpsModel,
    k: usize,
    selection: &Selection,
    churn: f64,
    seed: u64,
) -> Tensor {
    let mut churn_rng = Rng::for_stream(seed, era_solver::solvers::era::CHURN_STREAM);
    let mut times: Vec<f64> = Vec::new();
    let mut buf: Vec<Tensor> = Vec::new();
    let mut delta = match selection {
        Selection::ErrorRobust { lambda } => *lambda,
        _ => 1.0,
    };
    // Alg. 1 line 3: seed the buffer at (x_{t_0}, t_0).
    let e0 = eval(model, &x, grid[0]);
    times.push(grid[0]);
    buf.push(e0);
    let mut i = 0usize;
    loop {
        let (t_cur, t_next) = (grid[i], grid[i + 1]);
        let pred = if i < k - 1 {
            // Warmup: plain DDIM with the newest estimate.
            x = phi(sched, &x, buf.last().unwrap(), t_cur, t_next);
            i += 1;
            None
        } else {
            let bi = times.len() - 1;
            let idx: Vec<usize> = match selection {
                Selection::FixedLast => ((bi + 1 - k)..=bi).collect(),
                Selection::ErrorRobust { lambda } => select_indices(bi, k, delta / lambda),
                Selection::ConstantScale { scale } => select_indices(bi, k, *scale),
            };
            let nodes: Vec<f64> = idx.iter().map(|&n| times[n]).collect();
            let vals: Vec<&Tensor> = idx.iter().map(|&n| &buf[n]).collect();
            let eps_pred = lagrange::interpolate(&nodes, &vals, t_next);
            let n = buf.len();
            let order = n.min(3) + 1;
            let w = am_weights(order);
            let mut tensors: Vec<&Tensor> = vec![&eps_pred];
            for back in 0..order - 1 {
                tensors.push(&buf[n - 1 - back]);
            }
            let eps_c = Tensor::weighted_sum(&tensors, w);
            x = phi(sched, &x, &eps_c, t_cur, t_next);
            i += 1;
            Some(eps_pred)
        };
        // SDE churn on interior transitions (never the final one), using
        // the DDPM posterior std of the transition just taken.
        if churn > 0.0 && i + 1 < grid.len() {
            let ab_prev = sched.alpha_bar(grid[i - 1]);
            let ab_cur = sched.alpha_bar(grid[i]);
            let alpha = ab_prev / ab_cur;
            let var = (1.0 - ab_cur) / (1.0 - ab_prev) * (1.0 - alpha);
            if var > 0.0 {
                let z = churn_rng.normal_tensor(x.rows(), x.cols());
                x.axpy((churn * var.sqrt()) as f32, &z);
            }
        }
        if i + 1 >= grid.len() {
            break; // final evaluation skipped, as in Alg. 1
        }
        let e = eval(model, &x, grid[i]);
        if let Some(p) = pred {
            delta = e.mean_row_dist(&p) as f64;
        }
        times.push(grid[i]);
        buf.push(e);
    }
    x
}

fn drift(sched: &VpSchedule, x: &Tensor, eps: &Tensor, t: f64) -> Tensor {
    let beta = sched.beta_min + t * (sched.beta_max - sched.beta_min);
    let sigma = sched.sigma(t).max(1e-12);
    let mut f = x.clone();
    f.scale((-0.5 * beta) as f32);
    f.axpy((0.5 * beta / sigma) as f32, eps);
    f
}

fn ref_explicit_adams(
    sched: &VpSchedule,
    grid: &[f64],
    mut x: Tensor,
    model: &dyn EpsModel,
    pndm: bool,
) -> Tensor {
    let mut hist: Vec<Tensor> = Vec::new(); // newest first
    let push = |hist: &mut Vec<Tensor>, v: Tensor| {
        hist.insert(0, v);
        hist.truncate(4);
    };
    let mut i = 0usize;
    // Pseudo-RK warmup, 3 steps of 4 evaluations.
    for _ in 0..3 {
        let (t_cur, t_next) = (grid[i], grid[i + 1]);
        if pndm {
            let t_mid = 0.5 * (t_cur + t_next);
            let e1 = eval(model, &x, t_cur);
            push(&mut hist, e1.clone());
            let x1 = phi(sched, &x, &e1, t_cur, t_mid);
            let e2 = eval(model, &x1, t_mid);
            let x2 = phi(sched, &x, &e2, t_cur, t_mid);
            let e3 = eval(model, &x2, t_mid);
            let x3 = phi(sched, &x, &e3, t_cur, t_next);
            let e4 = eval(model, &x3, t_next);
            let combo = Tensor::weighted_sum(
                &[&e1, &e2, &e3, &e4],
                &[1.0 / 6.0, 2.0 / 6.0, 2.0 / 6.0, 1.0 / 6.0],
            );
            x = phi(sched, &x, &combo, t_cur, t_next);
        } else {
            let h = t_next - t_cur; // negative
            let f1 = drift(sched, &x, &eval(model, &x, t_cur), t_cur);
            push(&mut hist, f1.clone());
            let mut u = x.clone();
            u.axpy((0.5 * h) as f32, &f1);
            let f2 = drift(sched, &u, &eval(model, &u, t_cur + 0.5 * h), t_cur + 0.5 * h);
            let mut u = x.clone();
            u.axpy((0.5 * h) as f32, &f2);
            let f3 = drift(sched, &u, &eval(model, &u, t_cur + 0.5 * h), t_cur + 0.5 * h);
            let mut u = x.clone();
            u.axpy(h as f32, &f3);
            let f4 = drift(sched, &u, &eval(model, &u, t_next), t_next);
            let combo = Tensor::weighted_sum(
                &[&f1, &f2, &f3, &f4],
                &[1.0 / 6.0, 2.0 / 6.0, 2.0 / 6.0, 1.0 / 6.0],
            );
            x.axpy(h as f32, &combo);
        }
        i += 1;
    }
    // AB4 multistep phase.
    while i + 1 < grid.len() {
        let (t_cur, t_next) = (grid[i], grid[i + 1]);
        let val = if pndm {
            eval(model, &x, t_cur)
        } else {
            drift(sched, &x, &eval(model, &x, t_cur), t_cur)
        };
        push(&mut hist, val);
        assert_eq!(hist.len(), 4);
        let refs: Vec<&Tensor> = hist.iter().collect();
        let combo = Tensor::weighted_sum(&refs, &AB4);
        if pndm {
            x = phi(sched, &x, &combo, t_cur, t_next);
        } else {
            x.axpy((t_next - t_cur) as f32, &combo);
        }
        i += 1;
    }
    x
}

/// Order-1 DPM transfer (identical to the seed's `order1`).
fn dpm_order1(sched: &VpSchedule, x: &Tensor, eps: &Tensor, t_from: f64, t_to: f64) -> Tensor {
    let h = sched.lambda(t_to) - sched.lambda(t_from);
    let a = (sched.sqrt_alpha_bar(t_to) / sched.sqrt_alpha_bar(t_from)) as f32;
    let b = (-sched.sigma(t_to) * h.exp_m1()) as f32;
    x.affine(a, b, eps)
}

fn ref_dpm(
    sched: &VpSchedule,
    grid: &[f64],
    mut x: Tensor,
    model: &dyn EpsModel,
    orders: &[usize],
) -> Tensor {
    assert_eq!(orders.len() + 1, grid.len());
    for (i, &order) in orders.iter().enumerate() {
        let (tc, tn) = (grid[i], grid[i + 1]);
        let h = sched.lambda(tn) - sched.lambda(tc);
        let t_mid = |r: f64| sched.t_of_lambda(sched.lambda(tc) + r * h);
        match order {
            1 => {
                let e0 = eval(model, &x, tc);
                x = dpm_order1(sched, &x, &e0, tc, tn);
            }
            2 => {
                let e0 = eval(model, &x, tc);
                let s = t_mid(0.5);
                let u = dpm_order1(sched, &x, &e0, tc, s);
                let e1 = eval(model, &u, s);
                x = dpm_order1(sched, &x, &e1, tc, tn);
            }
            3 => {
                let (r1, r2) = (1.0 / 3.0, 2.0 / 3.0);
                let e0 = eval(model, &x, tc);
                let s1 = t_mid(r1);
                let u1 = dpm_order1(sched, &x, &e0, tc, s1);
                let e1 = eval(model, &u1, s1);
                let s2 = t_mid(r2);
                let a = sched.sqrt_alpha_bar(s2) / sched.sqrt_alpha_bar(tc);
                let sig = sched.sigma(s2);
                let em = (r2 * h).exp_m1();
                let mut u2 = x.affine(a as f32, (-sig * em) as f32, &e0);
                let c = -(sig * r2 / r1) * (em / (r2 * h) - 1.0);
                u2.axpy(c as f32, &e1);
                u2.axpy(-c as f32, &e0);
                let e2 = eval(model, &u2, s2);
                let a_f = sched.sqrt_alpha_bar(tn) / sched.sqrt_alpha_bar(tc);
                let sig_n = sched.sigma(tn);
                let em_h = h.exp_m1();
                let mut xn = x.affine(a_f as f32, (-sig_n * em_h) as f32, &e0);
                let c_f = -(sig_n / r2) * (em_h / h - 1.0);
                xn.axpy(c_f as f32, &e2);
                xn.axpy(-c_f as f32, &e0);
                x = xn;
            }
            _ => unreachable!(),
        }
    }
    x
}

fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.len(), b.len(), "shape mismatch");
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Drive the production solver for `name` and its reference twin from
/// identical priors; assert 1e-6 agreement.
fn check(name: &str, nfe: usize, grid_kind: GridKind, t_end: f64, model: &dyn EpsModel) {
    let sched = VpSchedule::default();
    let kind = SolverKind::parse(name).unwrap();
    let steps = kind.steps_for_nfe(nfe);
    let grid = make_grid(&sched, grid_kind, steps, 1.0, t_end);
    let seed = 42u64;
    let mut rng = Rng::new(9);
    let x0 = rng.normal_tensor(8, 2);

    let mut solver = kind.build(sched, grid.clone(), x0.clone(), seed, nfe);
    let got = sample_with(&mut *solver, model);

    let want = match &kind {
        SolverKind::Ddim => ref_ddim(&sched, &grid, x0, model),
        SolverKind::Ddpm => ref_ddpm(&sched, &grid, x0, model, seed),
        SolverKind::ImplicitAdams => ref_iadams(&sched, &grid, x0, model),
        SolverKind::Era { k, selection } => ref_era(&sched, &grid, x0, model, *k, selection),
        SolverKind::Pndm => ref_explicit_adams(&sched, &grid, x0, model, true),
        SolverKind::Fon => ref_explicit_adams(&sched, &grid, x0, model, false),
        SolverKind::Dpm { order } => {
            // Mirror SolverKind::make_plan's order-schedule choice.
            let orders = fixed_order_schedule(*order, nfe);
            let orders = if orders.len() + 1 == grid.len() {
                orders
            } else {
                vec![*order; grid.len() - 1]
            };
            ref_dpm(&sched, &grid, x0, model, &orders)
        }
        SolverKind::DpmFast => {
            let orders = fast_order_schedule(nfe);
            ref_dpm(&sched, &grid, x0, model, &orders)
        }
    };
    let d = max_abs_diff(&got, &want);
    assert!(
        d <= 1e-6,
        "{name} (nfe={nfe}, {grid_kind:?}, t_end={t_end}): max |diff| = {d}"
    );
}

#[test]
fn golden_every_solver_kind_exact_model() {
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    for name in [
        "ddim",
        "ddpm",
        "iadams",
        "era",
        "era-3",
        "era-fixed-4",
        "era-const-3@0.5",
        "dpm-1",
        "dpm-2",
        "dpm-3",
        "dpm-fast",
    ] {
        check(name, 12, GridKind::Uniform, 1e-3, &model);
    }
    for name in ["pndm", "fon"] {
        check(name, 15, GridKind::Uniform, 1e-3, &model);
    }
}

#[test]
fn golden_logsnr_grid_and_tight_t_end() {
    // The paper's CIFAR-10 configuration (logSNR grid, t_end 1e-4) for
    // the solvers the comparison runs there.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    for name in ["ddim", "era", "dpm-2", "dpm-3", "dpm-fast", "iadams"] {
        check(name, 12, GridKind::LogSnr, 1e-4, &model);
    }
}

#[test]
fn golden_era_under_model_error() {
    // The ERS selection path reacts to the measured error; a noisy
    // (deterministic) model exercises exponent warps the exact model
    // never reaches. Equivalence must hold along the whole decision
    // sequence, or the selections themselves diverged.
    let sched = VpSchedule::default();
    let model = NoisyEps::new(AnalyticGmm::gmm8(sched), 1.2, 2.0, 7);
    for name in ["era", "era-6@5", "era-fixed-5", "era-const-4@2"] {
        check(name, 15, GridKind::Uniform, 1e-3, &model);
    }
}

#[test]
fn golden_shared_plan_equals_private_plan() {
    // build() (private plan) vs build_with_plan() over a warm shared
    // cache: the cached plan must not drift from a freshly computed one.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let cache = era_solver::kernels::PlanCache::new();
    for name in ["era", "ddim", "dpm-fast", "iadams"] {
        let kind = SolverKind::parse(name).unwrap();
        let nfe = 12;
        let steps = kind.steps_for_nfe(nfe);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let mut rng = Rng::new(4);
        let x0 = rng.normal_tensor(16, 2);

        let mut direct = kind.build(sched, grid, x0.clone(), 4, nfe);
        let want = sample_with(&mut *direct, &model);
        for round in 0..2 {
            // Round 0 populates the cache; round 1 must hit it.
            let plan =
                kind.plan_from_cache(&cache, sched, GridKind::Uniform, nfe, 1.0, 1e-3);
            let mut cached = kind.build_with_plan(plan, x0.clone(), 4);
            let got = sample_with(&mut *cached, &model);
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "{name} round {round}: cached plan diverged"
            );
        }
    }
    assert!(cache.hits() >= 4, "second rounds must hit the cache");
}

/// Reference model for classifier-free guidance: each `eval` is the
/// combined `uncond + s * (cond - uncond)` of one cond and one uncond
/// evaluation — exactly what the production `Guided` wrapper feeds its
/// inner solver after splitting the paired slab output. Driving the
/// plain reference drivers with this model therefore restates the whole
/// guided trajectory.
struct GuidedRef<'a> {
    inner: &'a AnalyticGmm,
    scale: f32,
    class: usize,
}

impl EpsModel for GuidedRef<'_> {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        let c = self.inner.eval_cond(x, t, &vec![self.class as f32; x.rows()]);
        let u = self.inner.eval_cond(x, t, &vec![UNCOND; x.rows()]);
        let mut out = Tensor::zeros(x.rows(), x.cols());
        for ((o, &cv), &uv) in out.as_mut_slice().iter_mut().zip(c.as_slice()).zip(u.as_slice()) {
            *o = uv + self.scale * (cv - uv);
        }
        out
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }
}

#[test]
fn golden_guided_scale_zero_bitwise_unconditional() {
    // guidance_scale = 0 must route down the exact pre-existing path:
    // no paired rows, no wrapper, bit-identical samples and equal NFE.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    for name in ["era", "ddim", "dpm-2"] {
        let kind = SolverKind::parse(name).unwrap();
        let nfe = 12;
        let steps = kind.steps_for_nfe(nfe);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let plan = std::sync::Arc::new(kind.make_plan(sched, grid, nfe));
        let mut rng = Rng::new(21);
        let x0 = rng.normal_tensor(8, 2);

        let mut plain = kind.build_with_plan(plan.clone(), x0.clone(), 3);
        let want = sample_with(&mut *plain, &model);
        let task = TaskSpec { guidance_scale: 0.0, guide_class: 5, ..Default::default() };
        let mut zero = kind.build_task(plan, x0, 3, &task).unwrap();
        let got = sample_with(&mut *zero, &model);
        assert_eq!(got.as_slice(), want.as_slice(), "{name}: scale 0 must be bitwise plain");
        assert_eq!(zero.nfe(), plain.nfe(), "{name}: scale 0 must not double NFE");
    }
}

#[test]
fn golden_guided_matches_reference_driver() {
    // The paired-row production path (one 2N-row eval_cond per step,
    // split + guided_combine + truncate) vs the reference restatement
    // (two N-row evals combined manually, plain reference stepping).
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    for (name, scale, class) in [("ddim", 1.5f64, 2usize), ("era", 2.0, 6), ("era-3", 1.0, 0)] {
        let kind = SolverKind::parse(name).unwrap();
        let nfe = 12;
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let plan = std::sync::Arc::new(kind.make_plan(sched, grid.clone(), nfe));
        let mut rng = Rng::new(33);
        let x0 = rng.normal_tensor(8, 2);

        let task = TaskSpec {
            guidance_scale: scale,
            guide_class: class,
            ..Default::default()
        };
        let mut prod = kind.build_task(plan, x0.clone(), 9, &task).unwrap();
        let got = sample_with(&mut *prod, &model);
        assert_eq!(prod.nfe(), 2 * nfe, "{name}: paired evals count double");

        let guided_model = GuidedRef { inner: &model, scale: scale as f32, class };
        let want = match &kind {
            SolverKind::Ddim => ref_ddim(&sched, &grid, x0, &guided_model),
            SolverKind::Era { k, selection } => {
                ref_era(&sched, &grid, x0, &guided_model, *k, selection)
            }
            _ => unreachable!(),
        };
        let d = max_abs_diff(&got, &want);
        assert!(d <= 1e-6, "{name} scale {scale}: max |diff| = {d}");
    }
}

#[test]
fn golden_img2img_strength_buckets() {
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kind = SolverKind::parse("era").unwrap();
    let nfe = 12;
    let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
    let plan = std::sync::Arc::new(kind.make_plan(sched, grid.clone(), nfe));
    let mut rng = Rng::new(40);
    let noise = rng.normal_tensor(8, 2);
    let init = {
        let mut r = Rng::new(41);
        r.normal_tensor(8, 2)
    };

    // strength 1.0: bitwise the full trajectory (init ignored).
    let mut full = kind.build_with_plan(plan.clone(), noise.clone(), 2);
    let want_full = sample_with(&mut *full, &model);
    let t1 = TaskSpec { strength: 1.0, init: Some(init.clone()), ..Default::default() };
    let mut s1 = kind.build_task(plan.clone(), noise.clone(), 2, &t1).unwrap();
    let got_full = sample_with(&mut *s1, &model);
    assert_eq!(got_full.as_slice(), want_full.as_slice(), "strength 1.0 must be bitwise full");
    assert_eq!(s1.nfe(), nfe);

    // strength 0.5: suffix of the same grid from the noised init,
    // restated with the allocating reference driver.
    let t_half = TaskSpec { strength: 0.5, init: Some(init.clone()), ..Default::default() };
    let mut s_half = kind.build_task(plan.clone(), noise.clone(), 2, &t_half).unwrap();
    let got_half = sample_with(&mut *s_half, &model);
    assert_eq!(s_half.nfe(), nfe / 2, "strength 0.5 runs half the transitions");
    let start = nfe / 2;
    let t_start = grid[start];
    let a = sched.sqrt_alpha_bar(t_start) as f32;
    let b = sched.sigma(t_start) as f32;
    let mut x_start = Tensor::zeros(8, 2);
    for ((o, &iv), &nv) in x_start
        .as_mut_slice()
        .iter_mut()
        .zip(init.as_slice())
        .zip(noise.as_slice())
    {
        *o = a * iv + b * nv;
    }
    let want_half = match &kind {
        SolverKind::Era { k, selection } => {
            ref_era(&sched, &grid[start..], x_start, &model, *k, selection)
        }
        _ => unreachable!(),
    };
    let d = max_abs_diff(&got_half, &want_half);
    assert!(d <= 1e-6, "strength 0.5: max |diff| = {d}");

    // strength 0.0: zero transitions; bitwise the init noised to t_end.
    let t0 = TaskSpec { strength: 0.0, init: Some(init.clone()), ..Default::default() };
    let mut s0 = kind.build_task(plan, noise.clone(), 2, &t0).unwrap();
    let got_zero = sample_with(&mut *s0, &model);
    assert_eq!(s0.nfe(), 0);
    let t_end = *grid.last().unwrap();
    let (a, b) = (sched.sqrt_alpha_bar(t_end) as f32, sched.sigma(t_end) as f32);
    for ((got, &iv), &nv) in got_zero.as_slice().iter().zip(init.as_slice()).zip(noise.as_slice())
    {
        assert_eq!(*got, a * iv + b * nv, "strength 0 must be the re-noised init, bitwise");
    }
}

#[test]
fn golden_stochastic_era_pinned_against_reference() {
    // The churned trajectory, fixed seed, vs the reference driver that
    // replays the exact noise stream — pins the stream id, the fill
    // order and the posterior-std scaling.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    for (name, churn, seed) in [("era", 0.5f64, 7u64), ("era-3", 0.25, 11)] {
        let kind = SolverKind::parse(name).unwrap();
        let nfe = 14;
        let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
        let plan = std::sync::Arc::new(kind.make_plan(sched, grid.clone(), nfe));
        let mut rng = Rng::new(50);
        let x0 = rng.normal_tensor(8, 2);

        let task = TaskSpec { churn, ..Default::default() };
        let mut prod = kind.build_task(plan, x0.clone(), seed, &task).unwrap();
        let got = sample_with(&mut *prod, &model);

        let want = match &kind {
            SolverKind::Era { k, selection } => {
                ref_era_churn(&sched, &grid, x0, &model, *k, selection, churn, seed)
            }
            _ => unreachable!(),
        };
        let d = max_abs_diff(&got, &want);
        assert!(d <= 1e-6, "{name} churn {churn}: max |diff| = {d}");
    }
}

#[test]
fn golden_am_weights_computed_once_per_trajectory() {
    // Regression for the satellite: a full ERA trajectory consumes AM
    // weights at every corrected step, but the plan computes the table
    // exactly once; a second request on the shared plan adds zero.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kind = SolverKind::parse("era").unwrap();
    let nfe = 12;
    let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
    let plan = std::sync::Arc::new(kind.make_plan(sched, grid, nfe));
    for seed in [1u64, 2] {
        let mut rng = Rng::new(seed);
        let mut s = kind.build_with_plan(plan.clone(), rng.normal_tensor(8, 2), seed);
        let _ = sample_with(&mut *s, &model);
    }
    assert_eq!(plan.am_builds(), 1, "AM weights must be computed once per plan");
}
