//! Lane-engine equivalence suite: the batch-major struct-of-arrays
//! lanes (`solvers::lanes`) must reproduce the per-request boxed
//! [`Solver`] trajectories **bitwise** — for every solver kind, every
//! workload (guided pairing, img2img suffix plans, stochastic churn),
//! under ERA split-on-divergence, and under arbitrary admission/cancel
//! interleavings with mid-trajectory lane compaction.
//!
//! [`Solver`]: era_solver::solvers::Solver

use std::collections::HashMap;
use std::sync::Arc;

use era_solver::kernels::TrajectoryPlan;
use era_solver::rng::Rng;
use era_solver::solvers::eps_model::{AnalyticGmm, EpsModel, NoisyEps};
use era_solver::solvers::lanes::{LaneAdmission, LaneEngine, Removed};
use era_solver::solvers::schedule::{make_grid, GridKind, VpSchedule};
use era_solver::solvers::{sample_with, Solver, SolverKind, TaskSpec};
use era_solver::tensor::Tensor;

fn plan_for(kind: &SolverKind, nfe: usize) -> Arc<TrajectoryPlan> {
    let sched = VpSchedule::default();
    let steps = kind.steps_for_nfe(nfe);
    let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
    Arc::new(kind.make_plan(sched, grid, nfe))
}

fn prior(rows: usize, seed: u64) -> Tensor {
    let mut rng = Rng::for_stream(seed, 0x5eed);
    rng.normal_tensor(rows, 2)
}

fn admission(
    kind: &SolverKind,
    plan: Arc<TrajectoryPlan>,
    rows: usize,
    seed: u64,
    task: &TaskSpec,
) -> LaneAdmission {
    let res = kind.resolve_task(plan, prior(rows, seed), task).expect("resolve task");
    LaneAdmission {
        kind: kind.clone(),
        view: res.view,
        x: res.x,
        churn: res.churn,
        guided: res.guided,
        seed,
        conv_threshold: 0.0,
        min_nfe: 0,
    }
}

fn boxed(
    kind: &SolverKind,
    plan: Arc<TrajectoryPlan>,
    rows: usize,
    seed: u64,
    task: &TaskSpec,
) -> Box<dyn Solver> {
    kind.build_task(plan, prior(rows, seed), seed, task).expect("build solver")
}

/// Full-trajectory reference: `(samples, nfe, delta_eps)`.
fn reference(
    kind: &SolverKind,
    plan: Arc<TrajectoryPlan>,
    rows: usize,
    seed: u64,
    task: &TaskSpec,
    model: &dyn EpsModel,
) -> (Tensor, usize, Option<f64>) {
    let mut s = boxed(kind, plan, rows, seed, task);
    let out = sample_with(s.as_mut(), model);
    (out, s.nfe(), s.delta_eps())
}

/// Partial reference: drive `rounds` eval/deliver cycles, then (when
/// `plus_pull`) one further `next_eval` — the state a lane member holds
/// right after a pull (ERA advances its iterate at pull time).
#[allow(clippy::too_many_arguments)]
fn reference_partial(
    kind: &SolverKind,
    plan: Arc<TrajectoryPlan>,
    rows: usize,
    seed: u64,
    task: &TaskSpec,
    model: &dyn EpsModel,
    rounds: usize,
    plus_pull: bool,
) -> (Tensor, usize) {
    let mut s = boxed(kind, plan, rows, seed, task);
    let mut t_buf: Vec<f32> = Vec::new();
    for _ in 0..rounds {
        let Some(req) = s.next_eval() else { break };
        t_buf.clear();
        t_buf.resize(req.x.rows(), req.t as f32);
        let eps = match &req.cond {
            None => model.eval(&req.x, &t_buf),
            Some(c) => model.eval_cond(&req.x, &t_buf, c),
        };
        drop(req);
        s.on_eval(eps);
    }
    if plus_pull {
        let _ = s.next_eval();
    }
    (s.current().clone(), s.nfe())
}

/// Drive every lane of the engine to completion against `model`.
fn run_engine(eng: &mut LaneEngine, model: &dyn EpsModel) -> HashMap<usize, Removed> {
    let mut out = HashMap::new();
    let mut affected = Vec::new();
    loop {
        let mut progressed = false;
        for id in 0..eng.lane_slots() {
            if !eng.has_lane(id) {
                continue;
            }
            progressed = true;
            if eng.is_done(id) {
                for r in eng.finish_lane(id) {
                    out.insert(r.slot, r);
                }
                continue;
            }
            if eng.pending(id).is_none() {
                affected.clear();
                eng.step_lane(id, &mut affected);
                continue;
            }
            deliver_one(eng, id, model);
        }
        if !progressed {
            break;
        }
    }
    out
}

/// Evaluate and deliver one lane's pending request.
fn deliver_one(eng: &mut LaneEngine, id: usize, model: &dyn EpsModel) {
    let (x, t, cond) = {
        let req = eng.pending(id).expect("no pending eval");
        (Arc::clone(&req.x), req.t, req.cond.clone())
    };
    let t_buf = vec![t as f32; x.rows()];
    let eps = match &cond {
        None => model.eval(&x, &t_buf),
        Some(c) => model.eval_cond(&x, &t_buf, c),
    };
    drop(x);
    drop(cond);
    eng.deliver(id, eps);
}

#[test]
fn golden_lane_trajectories_every_solver_kind() {
    // Three same-config requests share one lane per kind; each member's
    // trajectory, NFE and delta_eps must be bitwise/exactly what its
    // own boxed solver produces.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kinds = [
        "ddpm",
        "ddim",
        "pndm",
        "fon",
        "iadams",
        "dpm-1",
        "dpm-2",
        "dpm-3",
        "dpm-fast",
        "era",
        "era-3@0.2",
        "era-6@5",
        "era-fixed-4",
        "era-const-3@0.5",
    ];
    for name in kinds {
        let kind = SolverKind::parse(name).unwrap();
        let nfe = 16.max(kind.min_nfe());
        let plan = plan_for(&kind, nfe);
        let task = TaskSpec::default();
        let mut eng = LaneEngine::new(0);
        let members = [(0usize, 3usize, 11u64), (1, 2, 12), (2, 4, 13)];
        for &(slot, rows, seed) in &members {
            eng.admit(slot, "gmm8", admission(&kind, plan.clone(), rows, seed, &task));
        }
        assert_eq!(eng.lane_count(), 1, "{name}: same config must share one lane");
        let out = run_engine(&mut eng, &model);
        for &(slot, rows, seed) in &members {
            let (want, want_nfe, want_delta) =
                reference(&kind, plan.clone(), rows, seed, &task, &model);
            let got = &out[&slot];
            assert_eq!(got.samples.as_slice(), want.as_slice(), "{name} slot {slot}");
            assert_eq!(got.nfe, want_nfe, "{name} slot {slot} nfe");
            assert_eq!(got.delta_eps, want_delta, "{name} slot {slot} delta_eps");
        }
    }
}

#[test]
fn golden_lane_workloads_guided_img2img_stochastic() {
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let nfe = 14;

    // Guided: two members with *different* scales and classes share a
    // lane (guidance is per-member row-local state).
    let era = SolverKind::parse("era").unwrap();
    let plan = plan_for(&era, nfe);
    let g1 = TaskSpec { guidance_scale: 2.0, guide_class: 2, ..Default::default() };
    let g2 = TaskSpec { guidance_scale: 1.0, guide_class: 5, ..Default::default() };
    let mut eng = LaneEngine::new(0);
    eng.admit(0, "gmm8", admission(&era, plan.clone(), 4, 21, &g1));
    eng.admit(1, "gmm8", admission(&era, plan.clone(), 3, 22, &g2));
    assert_eq!(eng.lane_count(), 1, "guided members must fuse into one lane");
    let out = run_engine(&mut eng, &model);
    for (slot, rows, seed, task) in [(0usize, 4usize, 21u64, &g1), (1, 3, 22, &g2)] {
        let (want, want_nfe, want_delta) =
            reference(&era, plan.clone(), rows, seed, task, &model);
        assert_eq!(out[&slot].samples.as_slice(), want.as_slice(), "guided slot {slot}");
        assert_eq!(out[&slot].nfe, want_nfe, "guided nfe doubles per paired eval");
        assert_eq!(out[&slot].delta_eps, want_delta);
    }

    // img2img: two strengths = two suffix views = two lanes, both
    // bitwise equal to their boxed suffix trajectories.
    let ddim = SolverKind::Ddim;
    let plan_d = plan_for(&ddim, nfe);
    let img = |strength: f64, rows: usize| TaskSpec {
        strength,
        init: Some(Tensor::from_vec(vec![0.5; rows * 2], rows, 2)),
        ..Default::default()
    };
    let t_half = img(0.5, 4);
    let t_quarter = img(0.25, 2);
    let mut eng = LaneEngine::new(0);
    eng.admit(0, "gmm8", admission(&ddim, plan_d.clone(), 4, 31, &t_half));
    eng.admit(1, "gmm8", admission(&ddim, plan_d.clone(), 2, 32, &t_quarter));
    assert_eq!(eng.lane_count(), 2, "distinct suffix starts must not share a lane");
    let out = run_engine(&mut eng, &model);
    for (slot, rows, seed, task) in [(0usize, 4usize, 31u64, &t_half), (1, 2, 32, &t_quarter)] {
        let (want, want_nfe, _) = reference(&ddim, plan_d.clone(), rows, seed, task, &model);
        assert_eq!(out[&slot].samples.as_slice(), want.as_slice(), "img2img slot {slot}");
        assert_eq!(out[&slot].nfe, want_nfe);
    }

    // Stochastic churn: per-member streams inside one lane.
    let sde = TaskSpec { churn: 0.4, ..Default::default() };
    let mut eng = LaneEngine::new(0);
    eng.admit(0, "gmm8", admission(&era, plan.clone(), 3, 41, &sde));
    eng.admit(1, "gmm8", admission(&era, plan.clone(), 3, 42, &sde));
    // Mixed churn levels in one lane: a deterministic member rides
    // along untouched by its batch-mates' noise.
    eng.admit(2, "gmm8", admission(&era, plan.clone(), 2, 43, &TaskSpec::default()));
    assert_eq!(eng.lane_count(), 1);
    let out = run_engine(&mut eng, &model);
    for (slot, rows, seed, task) in
        [(0usize, 3usize, 41u64, &sde), (1, 3, 42, &sde), (2, 2, 43, &TaskSpec::default())]
    {
        let (want, want_nfe, want_delta) =
            reference(&era, plan.clone(), rows, seed, task, &model);
        assert_eq!(out[&slot].samples.as_slice(), want.as_slice(), "sde slot {slot}");
        assert_eq!(out[&slot].nfe, want_nfe);
        assert_eq!(out[&slot].delta_eps, want_delta);
    }

    // strength = 0: the zero-transition lane returns the re-noised init
    // with zero evaluations, exactly like the boxed Noop path.
    let zero = img(0.0, 2);
    let mut eng = LaneEngine::new(0);
    eng.admit(0, "gmm8", admission(&ddim, plan_d.clone(), 2, 51, &zero));
    let out = run_engine(&mut eng, &model);
    let (want, want_nfe, _) = reference(&ddim, plan_d, 2, 51, &zero, &model);
    assert_eq!(out[&0].samples.as_slice(), want.as_slice());
    assert_eq!(out[&0].nfe, want_nfe);
    assert_eq!(out[&0].nfe, 0);
}

#[test]
fn golden_era_split_on_divergence_under_model_error() {
    // A noisy model drives per-member delta_eps apart; the lane must
    // split into sibling lanes when selections diverge and every
    // member must still match its boxed solver bitwise — including the
    // reported delta_eps.
    let sched = VpSchedule::default();
    let model = NoisyEps::new(AnalyticGmm::gmm8(sched), 1.2, 2.0, 9);
    for name in ["era-4@0.3", "era-6@0.3"] {
        let kind = SolverKind::parse(name).unwrap();
        let nfe = 18;
        let plan = plan_for(&kind, nfe);
        let task = TaskSpec::default();
        let mut eng = LaneEngine::new(0);
        let members: Vec<(usize, usize, u64)> =
            (0..6).map(|i| (i, 2 + i % 3, 60 + i as u64)).collect();
        for &(slot, rows, seed) in &members {
            eng.admit(slot, "gmm8", admission(&kind, plan.clone(), rows, seed, &task));
        }
        assert_eq!(eng.lane_count(), 1);
        let out = run_engine(&mut eng, &model);
        for &(slot, rows, seed) in &members {
            let (want, want_nfe, want_delta) =
                reference(&kind, plan.clone(), rows, seed, &task, &model);
            assert_eq!(out[&slot].samples.as_slice(), want.as_slice(), "{name} slot {slot}");
            assert_eq!(out[&slot].nfe, want_nfe);
            assert_eq!(out[&slot].delta_eps, want_delta);
        }
    }
}

/// Wrapper whose conditional head poisons rows of one guide class with
/// NaN — a stand-in for a model producing non-finite eps under rare
/// inputs. Unconditional rows and other classes pass through clean.
struct NanClassEps {
    inner: AnalyticGmm,
    poison_class: f32,
}

impl EpsModel for NanClassEps {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        self.inner.eval(x, t)
    }

    fn eval_cond(&self, x: &Tensor, t: &[f32], c: &[f32]) -> Tensor {
        let mut out = self.inner.eval_cond(x, t, c);
        for (r, &cv) in c.iter().enumerate() {
            if cv == self.poison_class {
                for v in out.row_mut(r) {
                    *v = f32::NAN;
                }
            }
        }
        out
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }
}

fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn golden_nan_member_degrades_deterministically_and_spares_batch_mates() {
    // One lane member's eps goes NaN mid-trajectory; its error-measure
    // exponent is then non-finite and the guarded ERA selection must
    // fall back to the newest-k window deterministically — on both the
    // lane and the boxed path, so the two still agree bit-for-bit —
    // while the clean batch-mate's rows stay finite and untouched.
    let sched = VpSchedule::default();
    let model = NanClassEps { inner: AnalyticGmm::gmm8(sched), poison_class: 7.0 };
    let kind = SolverKind::parse("era-4@0.3").unwrap();
    let plan = plan_for(&kind, 14);
    let clean = TaskSpec { guidance_scale: 1.5, guide_class: 2, ..Default::default() };
    let poisoned = TaskSpec { guidance_scale: 1.5, guide_class: 7, ..Default::default() };
    let mut eng = LaneEngine::new(0);
    eng.admit(0, "gmm8", admission(&kind, plan.clone(), 3, 71, &clean));
    eng.admit(1, "gmm8", admission(&kind, plan.clone(), 2, 72, &poisoned));
    let out = run_engine(&mut eng, &model);
    for (slot, rows, seed, task) in [(0usize, 3usize, 71u64, &clean), (1, 2, 72, &poisoned)] {
        let (want, want_nfe, want_delta) =
            reference(&kind, plan.clone(), rows, seed, task, &model);
        let got = &out[&slot];
        // Bit-pattern comparison: NaN != NaN would fail assert_eq even
        // on identical trajectories.
        assert_eq!(
            f32_bits(got.samples.as_slice()),
            f32_bits(want.as_slice()),
            "slot {slot} diverged from its boxed reference"
        );
        assert_eq!(got.nfe, want_nfe, "slot {slot} nfe");
        match (got.delta_eps, want_delta) {
            (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "slot {slot} delta"),
            (a, b) => assert_eq!(a.is_none(), b.is_none(), "slot {slot} delta presence"),
        }
    }
    // The clean member is insulated from its poisoned batch-mate.
    assert!(out[&0].samples.as_slice().iter().all(|v| v.is_finite()));
    assert!(out[&1].samples.as_slice().iter().any(|v| v.is_nan()));
}

#[test]
fn prop_early_stop_compaction_never_changes_survivor_bits() {
    // Property run for the convergence controller's retirement path:
    // random ERA configs and member mixes; one member is QoS-degraded
    // at a random round, retires through `finish_member_early` (closing
    // DDIM jump + compaction), and every survivor must still finish
    // bitwise identical to its boxed fixed-NFE solver.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kinds = ["era", "era-3@0.2", "era-6@5"];
    let mut prng = Rng::new(0xC0FFEE);
    for case in 0..20 {
        let kind = SolverKind::parse(kinds[prng.below(kinds.len() as u64) as usize]).unwrap();
        let nfe = 10 + prng.below(6) as usize;
        let plan = plan_for(&kind, nfe);
        let task = TaskSpec::default();
        let floor = 2 + prng.below(4) as usize;
        let n_members = 2 + prng.below(3) as usize;
        let members: Vec<(usize, usize, u64)> = (0..n_members)
            .map(|i| (i, 1 + prng.below(4) as usize, 900 * case as u64 + i as u64))
            .collect();
        let mut eng = LaneEngine::new(0);
        for &(slot, rows, seed) in &members {
            let mut adm = admission(&kind, plan.clone(), rows, seed, &task);
            adm.min_nfe = floor;
            eng.admit(slot, "gmm8", adm);
        }
        let victim = members[prng.below(n_members as u64) as usize].0;
        let degrade_round = 1 + prng.below((nfe - 1) as u64) as usize;
        let mut stopped: Option<Removed> = None;
        let mut rounds = 0usize;
        let mut affected = Vec::new();
        loop {
            let mut any_pending = false;
            for id in 0..eng.lane_slots() {
                if eng.has_lane(id) && !eng.is_done(id) && eng.pending(id).is_none() {
                    affected.clear();
                    eng.step_lane(id, &mut affected);
                }
                if eng.has_lane(id) && eng.pending(id).is_some() {
                    any_pending = true;
                }
            }
            if !any_pending {
                break;
            }
            for id in 0..eng.lane_slots() {
                if eng.has_lane(id) && eng.pending(id).is_some() {
                    deliver_one(&mut eng, id, &model);
                }
            }
            rounds += 1;
            // Latch the victim at its random round; the controller then
            // retires it at the first post-deliver check at/after the
            // floor.
            if rounds == degrade_round && stopped.is_none() {
                assert!(eng.degrade_member(victim), "case {case}: degrade refused");
            }
            if stopped.is_none() {
                if let Some(lane) = eng.lane_of_slot(victim) {
                    let conv = eng.converged_members(lane);
                    assert!(
                        conv.iter().all(|&s| s == victim),
                        "case {case}: non-degraded member reported converged"
                    );
                    if conv.contains(&victim) {
                        stopped = Some(eng.finish_member_early(lane, victim));
                    }
                }
            }
            assert!(rounds < 200, "case {case}: runaway");
        }
        let got = stopped.unwrap_or_else(|| panic!("case {case}: victim never retired early"));
        assert!(got.early_stop, "case {case}: early-stop marker missing");
        assert_eq!(
            got.nfe,
            degrade_round.max(floor),
            "case {case}: degraded member must retire at the first checked step at/after its floor"
        );
        assert!(got.samples.as_slice().iter().all(|v| v.is_finite()), "case {case}");
        // Collect finished lanes; every survivor must be bit-exact.
        let mut out = HashMap::new();
        for id in 0..eng.lane_slots() {
            if eng.has_lane(id) && eng.is_done(id) {
                for r in eng.finish_lane(id) {
                    out.insert(r.slot, r);
                }
            }
        }
        for &(slot, rows, seed) in &members {
            if slot == victim {
                continue;
            }
            let (want, want_nfe, want_delta) =
                reference(&kind, plan.clone(), rows, seed, &task, &model);
            let sv = out.get(&slot).unwrap_or_else(|| panic!("case {case}: {slot} missing"));
            assert!(!sv.early_stop, "case {case}: survivor {slot} marked early_stop");
            assert_eq!(
                sv.samples.as_slice(),
                want.as_slice(),
                "case {case}: survivor {slot} perturbed by early-stop compaction"
            );
            assert_eq!(sv.nfe, want_nfe, "case {case} survivor {slot} nfe");
            assert_eq!(sv.delta_eps, want_delta, "case {case} survivor {slot} delta_eps");
        }
    }
}

#[test]
fn prop_admission_cancel_interleavings_never_change_surviving_bits() {
    // Hand-rolled property run: random kinds, member mixes, and
    // cancellation points (both at round boundaries and right after a
    // pull, which exercises pending regeneration after compaction).
    // Every cancelled member's partial iterate and every survivor's
    // final output must be bitwise identical to a boxed solver driven
    // to the same point.
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kinds = ["ddim", "ddpm", "iadams", "dpm-2", "era", "era-3@0.2"];
    let mut prng = Rng::new(0xC0FFEE);
    for case in 0..30 {
        let kind = SolverKind::parse(kinds[prng.below(kinds.len() as u64) as usize]).unwrap();
        let nfe = 10 + prng.below(6) as usize;
        let plan = plan_for(&kind, nfe);
        let guided = matches!(kind, SolverKind::Era { .. }) && prng.below(3) == 0;
        let task = if guided {
            TaskSpec { guidance_scale: 1.5, guide_class: 1, ..Default::default() }
        } else {
            TaskSpec::default()
        };
        let n_members = 2 + prng.below(3) as usize;
        let members: Vec<(usize, usize, u64)> = (0..n_members)
            .map(|i| (i, 1 + prng.below(4) as usize, 100 * case as u64 + i as u64))
            .collect();
        let mut eng = LaneEngine::new(0);
        for &(slot, rows, seed) in &members {
            eng.admit(slot, "gmm8", admission(&kind, plan.clone(), rows, seed, &task));
        }
        let mut alive: Vec<usize> = members.iter().map(|&(s, _, _)| s).collect();
        let mut rounds = 0usize;
        let mut affected = Vec::new();
        // Interleave stepping with random cancellations.
        loop {
            // Cancel at a round boundary (pending None everywhere).
            // Members of already-finished lanes are left to retire
            // normally — their state includes ERA's final advance,
            // which the partial reference does not model.
            if alive.len() > 1 && prng.below(4) == 0 {
                let pick = prng.below(alive.len() as u64) as usize;
                let slot = alive[pick];
                let lane = eng.lane_of_slot(slot).expect("live member has a lane");
                if !eng.is_done(lane) {
                    alive.remove(pick);
                    let removed = eng.remove_member(lane, slot, None);
                    let (want, want_nfe) = reference_partial(
                        &kind,
                        plan.clone(),
                        member_rows(&members, slot),
                        member_seed(&members, slot),
                        &task,
                        &model,
                        rounds,
                        false,
                    );
                    assert_eq!(
                        removed.samples.as_slice(),
                        want.as_slice(),
                        "case {case}: boundary-cancelled member {slot} diverged"
                    );
                    assert_eq!(removed.nfe, want_nfe, "case {case} slot {slot} nfe");
                }
            }
            // Step every lane.
            let mut any_pending = false;
            for id in 0..eng.lane_slots() {
                if eng.has_lane(id) && !eng.is_done(id) && eng.pending(id).is_none() {
                    affected.clear();
                    eng.step_lane(id, &mut affected);
                }
                if eng.has_lane(id) && eng.pending(id).is_some() {
                    any_pending = true;
                }
            }
            if !any_pending {
                break; // every lane finished (or emptied)
            }
            // Cancel right after a pull: pending must be regenerated
            // from the compacted state for the survivors.
            if alive.len() > 1 && prng.below(5) == 0 {
                let pick = prng.below(alive.len() as u64) as usize;
                let slot = alive[pick];
                let lane = eng.lane_of_slot(slot).expect("live member has a lane");
                if !eng.is_done(lane) && eng.pending(lane).is_some() {
                    alive.remove(pick);
                    let removed = eng.remove_member(lane, slot, None);
                    let (want, want_nfe) = reference_partial(
                        &kind,
                        plan.clone(),
                        member_rows(&members, slot),
                        member_seed(&members, slot),
                        &task,
                        &model,
                        rounds,
                        true,
                    );
                    assert_eq!(
                        removed.samples.as_slice(),
                        want.as_slice(),
                        "case {case}: post-pull-cancelled member {slot} diverged"
                    );
                    assert_eq!(removed.nfe, want_nfe, "case {case} slot {slot} nfe");
                }
            }
            // Deliver every pending lane evaluation.
            for id in 0..eng.lane_slots() {
                if eng.has_lane(id) && eng.pending(id).is_some() {
                    deliver_one(&mut eng, id, &model);
                }
            }
            rounds += 1;
            assert!(rounds < 200, "case {case}: runaway");
        }
        // Collect finished lanes and check the survivors.
        let mut out = HashMap::new();
        for id in 0..eng.lane_slots() {
            if eng.has_lane(id) && eng.is_done(id) {
                for r in eng.finish_lane(id) {
                    out.insert(r.slot, r);
                }
            }
        }
        for &slot in &alive {
            let (want, want_nfe, want_delta) = reference(
                &kind,
                plan.clone(),
                member_rows(&members, slot),
                member_seed(&members, slot),
                &task,
                &model,
            );
            let got = out.get(&slot).unwrap_or_else(|| panic!("case {case}: {slot} missing"));
            assert_eq!(
                got.samples.as_slice(),
                want.as_slice(),
                "case {case}: survivor {slot} perturbed by compaction"
            );
            assert_eq!(got.nfe, want_nfe, "case {case} survivor {slot} nfe");
            assert_eq!(got.delta_eps, want_delta, "case {case} survivor {slot} delta_eps");
        }
    }
}

fn member_rows(members: &[(usize, usize, u64)], slot: usize) -> usize {
    members.iter().find(|m| m.0 == slot).unwrap().1
}

fn member_seed(members: &[(usize, usize, u64)], slot: usize) -> u64 {
    members.iter().find(|m| m.0 == slot).unwrap().2
}
