//! Integration tests over the readiness gateway: the same wire
//! protocol as `integration_server.rs`, served by epoll event loops
//! instead of a thread per connection. The stock blocking [`Client`]
//! drives everything — wire compatibility is the point — plus
//! gateway-specific behaviours: connection multiplexing far past the
//! io-thread count, reply interleaving for pipelined connections,
//! polite over-cap rejection, and the connection telemetry gauges.

#![cfg(target_os = "linux")]

use std::sync::Arc;
use std::time::Duration;

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, RequestSpec};
use era_solver::metrics;
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::server::client::{generate_load, Client};
use era_solver::server::gateway::{Gateway, GatewayConfig};
use era_solver::server::protocol::Encoding;
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;
use era_solver::solvers::TaskSpec;
use era_solver::tensor::Tensor;

fn mock_pool(shards: usize, config: CoordinatorConfig) -> Arc<WorkerPool> {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> =
        Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
    Arc::new(WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard: config,
            max_inflight_rows: 0,
        },
    ))
}

fn gw_stack(shards: usize, config: CoordinatorConfig) -> (Gateway, Arc<WorkerPool>) {
    let pool = mock_pool(shards, config);
    let gw = Gateway::start(pool.clone(), GatewayConfig::default()).expect("bind gateway");
    (gw, pool)
}

fn spec(n: usize, seed: u64) -> RequestSpec {
    RequestSpec { n_samples: n, seed, ..Default::default() }
}

#[test]
fn ping_stats_and_sample_roundtrip() {
    let (gw, _pool) = gw_stack(1, CoordinatorConfig::default());
    let mut c = Client::connect(gw.local_addr()).unwrap();
    c.ping().unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("finished").as_usize(), Some(0));
    let (samples, secs) = c.sample(&spec(300, 4)).unwrap();
    assert_eq!((samples.rows(), samples.cols()), (300, 2));
    assert!(secs >= 0.0);
    let cov = metrics::mode_coverage(&samples, &era_solver::data::gmm8_modes(), 0.5);
    assert!(cov > 0.9, "coverage {cov}");
    gw.shutdown();
}

#[test]
fn gateway_samples_match_the_in_process_solver_bitwise() {
    // Strongest wire-compat check: the gateway path must be numerically
    // identical to driving the solver directly (same seed, same model).
    let (gw, _pool) = gw_stack(1, CoordinatorConfig::default());
    let mut c = Client::connect(gw.local_addr()).unwrap();
    let s = spec(64, 9);
    let (samples, _) = c.sample(&s).unwrap();

    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let mut solver = s.build_solver(sched, 2).unwrap();
    let direct = era_solver::solvers::sample_with(&mut *solver, &model);
    assert_eq!(samples.as_slice(), direct.as_slice());
    gw.shutdown();
}

#[test]
fn malformed_lines_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (gw, _pool) = gw_stack(1, CoordinatorConfig::default());
    let stream = std::net::TcpStream::connect(gw.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for bad in ["not json", "{\"op\":\"nope\"}", "{\"op\":\"sample\",\"solver\":\"wat\"}"] {
        writeln!(writer, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = era_solver::json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false), "line: {bad}");
        assert!(j.get("error").as_str().is_some());
    }
    gw.shutdown();
}

#[test]
fn pipelined_control_ops_answer_while_a_sample_is_in_flight() {
    // A pipelining connection sends a slow sample then a ping without
    // reading in between. The blocking path would serialise; the
    // gateway answers the ping immediately — no blocking reads, no
    // per-request parking.
    use std::io::{BufRead, BufReader, Write};
    let cfg = CoordinatorConfig {
        policy: BatchPolicy {
            max_rows: 8192,
            min_rows: 4096, // parks the sample until cancel/shutdown
            max_wait: Duration::from_secs(5),
        },
        ..Default::default()
    };
    let (gw, pool) = gw_stack(1, cfg);
    let stream = std::net::TcpStream::connect(gw.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let slow = br#"{"op":"sample","dataset":"gmm8","n_samples":16,"seed":1,"tag":31}"#;
    writer.write_all(slow).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let first = era_solver::json::parse(&line).unwrap();
    assert_eq!(first.get("pong").as_bool(), Some(true), "ping must overtake the parked sample");
    // Unpark the sample by cancelling it; its (cancelled) reply arrives.
    assert!(pool.cancel_tag(31));
    line.clear();
    reader.read_line(&mut line).unwrap();
    let second = era_solver::json::parse(&line).unwrap();
    assert_eq!(second.get("ok").as_bool(), Some(true));
    assert_eq!(second.get("cancelled").as_bool(), Some(true));
    gw.shutdown();
}

#[test]
fn concurrent_clients_all_served_with_fusion() {
    let cfg = CoordinatorConfig {
        max_active: 16,
        queue_capacity: 64,
        policy: BatchPolicy {
            max_rows: 256,
            min_rows: 32,
            max_wait: Duration::from_millis(5),
        },
        ..Default::default()
    };
    let (gw, pool) = gw_stack(1, cfg);
    let report = generate_load(gw.local_addr(), &spec(32, 0), 6, 4);
    assert_eq!(report.errors, 0, "all requests should succeed");
    assert_eq!(report.requests, 24);
    assert!(report.throughput_rows > 0.0);
    // Cross-request fusion must have happened under this load.
    assert!(pool.stats().occupancy() > 32.0, "occupancy {}", pool.stats().occupancy());
    gw.shutdown();
}

#[test]
fn many_idle_connections_multiplex_on_two_io_threads() {
    let (gw, pool) = gw_stack(1, CoordinatorConfig::default());
    let mut idle = Vec::new();
    for _ in 0..100 {
        idle.push(Client::connect(gw.local_addr()).unwrap());
    }
    // The gauge counts every open connection (poll briefly: accepts
    // finish on the event loops, not in connect()).
    let mut open = 0;
    for _ in 0..500 {
        open = pool.conn_snapshot().open_connections;
        if open >= 100 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(open >= 100, "open_connections gauge {open}");
    // Service stays live across the idle herd, on every connection.
    let mut active = Client::connect(gw.local_addr()).unwrap();
    let (samples, _) = active.sample(&spec(16, 7)).unwrap();
    assert_eq!(samples.rows(), 16);
    idle.last_mut().unwrap().ping().unwrap();
    idle[0].ping().unwrap();
    drop(idle);
    // Disconnects drain the gauge.
    let mut open = usize::MAX;
    for _ in 0..500 {
        open = pool.conn_snapshot().open_connections;
        if open <= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(open <= 1, "gauge after disconnects {open}");
    let snap = pool.conn_snapshot();
    assert!(snap.accepted_total >= 101, "accepted {}", snap.accepted_total);
    gw.shutdown();
}

#[test]
fn over_cap_connections_get_the_overloaded_error() {
    use std::io::{BufRead, BufReader};
    let pool = mock_pool(1, CoordinatorConfig::default());
    let gw = Gateway::start(
        pool.clone(),
        GatewayConfig { max_connections: 2, ..GatewayConfig::default() },
    )
    .unwrap();
    let mut keep = Vec::new();
    for _ in 0..2 {
        let mut c = Client::connect(gw.local_addr()).unwrap();
        c.ping().unwrap(); // forces the accept to have happened
        keep.push(c);
    }
    let extra = std::net::TcpStream::connect(gw.local_addr()).unwrap();
    let mut reader = BufReader::new(extra);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = era_solver::json::parse(&line).unwrap();
    assert_eq!(j.get("ok").as_bool(), Some(false));
    assert_eq!(j.get("error").as_str(), Some("server overloaded"));
    assert!(pool.conn_snapshot().rejected_total >= 1);
    gw.shutdown();
}

#[test]
fn cross_connection_cancel_and_trace_through_the_gateway() {
    // Mirrors the blocking path's cancelled-trace test: a request
    // parked behind a huge min_rows policy is cancelled by tag from a
    // second connection; the submitter gets its partial cancelled
    // reply and the trace is terminal at the cancel event.
    let cfg = CoordinatorConfig {
        policy: BatchPolicy {
            max_rows: 8192,
            min_rows: 4096,
            max_wait: Duration::from_secs(5),
        },
        ..Default::default()
    };
    let (gw, _pool) = gw_stack(1, cfg);
    let addr = gw.local_addr();
    let tag = 9001u64;
    let submitter = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.sample_tagged(&spec(16, 1), Some(tag)).unwrap()
    });
    let mut c2 = Client::connect(addr).unwrap();
    let mut cancelled = false;
    for _ in 0..500 {
        if c2.cancel(tag).unwrap() {
            cancelled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(cancelled, "tag never registered");
    let out = submitter.join().unwrap();
    assert!(out.cancelled);
    let trace = c2.trace(tag).unwrap();
    let events = trace.get("events").as_arr().unwrap();
    let kinds: Vec<&str> = events.iter().map(|e| e.get("kind").as_str().unwrap()).collect();
    assert_eq!(kinds.last(), Some(&"cancelled"), "kinds: {kinds:?}");
    assert_eq!(kinds.iter().filter(|k| **k == "cancelled").count(), 1);
    gw.shutdown();
}

#[test]
fn disconnect_mid_session_and_mid_request_is_harmless() {
    let (gw, pool) = gw_stack(1, CoordinatorConfig::default());
    {
        let mut c = Client::connect(gw.local_addr()).unwrap();
        c.ping().unwrap();
        // drop without closing politely
    }
    {
        use std::io::Write;
        // Drop with a request still in flight: the gateway aborts the
        // session and cancels its ticket.
        let mut stream = std::net::TcpStream::connect(gw.local_addr()).unwrap();
        stream
            .write_all(b"{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":8,\"seed\":3}\n")
            .unwrap();
    }
    let mut c2 = Client::connect(gw.local_addr()).unwrap();
    let (samples, _) = c2.sample(&spec(8, 1)).unwrap();
    assert_eq!(samples.rows(), 8);
    drop(c2);
    let mut open = usize::MAX;
    for _ in 0..500 {
        open = pool.conn_snapshot().open_connections;
        if open == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(open, 0, "all disconnects must drain the gauge");
    gw.shutdown();
}

#[test]
fn stats_and_metrics_carry_connection_telemetry() {
    let (gw, _pool) = gw_stack(2, CoordinatorConfig::default());
    let mut c = Client::connect(gw.local_addr()).unwrap();
    let (samples, _) = c.sample(&spec(16, 5)).unwrap();
    assert_eq!(samples.rows(), 16);
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("shards").as_usize(), Some(2));
    assert_eq!(stats.get("finished").as_usize(), Some(1));
    let conns = stats.get("connections");
    assert!(conns.get("open").as_usize().unwrap_or(0) >= 1, "{}", stats.to_string());
    assert!(conns.get("accepted").as_usize().unwrap_or(0) >= 1);
    let shards = c.shards().unwrap();
    assert!(shards.get("connections").get("accepted").as_usize().unwrap_or(0) >= 1);
    let text = c.metrics().unwrap();
    assert!(text.contains("# TYPE era_open_connections gauge"), "{text}");
    assert!(text.contains("# TYPE era_connections_accepted_total counter"));
    assert!(text.contains("# TYPE era_backpressure_stalls_total counter"));
    gw.shutdown();
}

#[test]
fn oversized_request_line_is_refused_and_the_connection_closed() {
    use std::io::{BufRead, BufReader, Write};
    let pool = mock_pool(1, CoordinatorConfig::default());
    let gw = Gateway::start(
        pool,
        GatewayConfig { max_frame_len: 1024, ..GatewayConfig::default() },
    )
    .unwrap();
    let stream = std::net::TcpStream::connect(gw.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let blob = vec![b'x'; 4096]; // no newline: an unframed hostile blob
    writer.write_all(&blob).unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = era_solver::json::parse(&line).unwrap();
    assert_eq!(j.get("ok").as_bool(), Some(false));
    assert!(j.get("error").as_str().unwrap_or("").contains("frame exceeds"), "{line}");
    // The server closes after the error: next read is EOF.
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must close");
    gw.shutdown();
}

#[test]
fn binary_and_json_deliveries_are_bitwise_identical() {
    // The binary payload carries the result's raw f32 bits; the JSON
    // path's shortest-round-trip decimals decode to the same bits — so
    // the two encodings of one seeded request must agree exactly.
    let (gw, _pool) = gw_stack(1, CoordinatorConfig::default());
    let s = spec(64, 21);
    let mut jc = Client::connect(gw.local_addr()).unwrap();
    let (json_samples, _) = jc.sample(&s).unwrap();
    let mut bc = Client::connect(gw.local_addr()).unwrap();
    bc.set_encoding(Encoding::Bin);
    let (bin_samples, _) = bc.sample(&s).unwrap();
    assert_eq!((bin_samples.rows(), bin_samples.cols()), (64, 2));
    assert_eq!(bin_samples.as_slice(), json_samples.as_slice());
    gw.shutdown();
}

#[test]
fn binary_init_upload_matches_json_init_through_the_gateway() {
    // img2img with the init batch uploaded as a counted binary payload
    // must land on the same trajectory as the JSON-rows upload.
    let (gw, _pool) = gw_stack(1, CoordinatorConfig::default());
    let init = Tensor::from_vec((0..64).map(|i| (i as f32) * 0.25 - 8.0).collect(), 32, 2);
    let task = TaskSpec { strength: 0.5, init: Some(init), ..Default::default() };
    let s = RequestSpec { n_samples: 32, seed: 3, task, ..Default::default() };
    let mut jc = Client::connect(gw.local_addr()).unwrap();
    let (json_samples, _) = jc.sample(&s).unwrap();
    let mut bc = Client::connect(gw.local_addr()).unwrap();
    bc.set_encoding(Encoding::Bin);
    let (bin_samples, _) = bc.sample(&s).unwrap();
    assert_eq!(bin_samples.as_slice(), json_samples.as_slice());
    gw.shutdown();
}

#[test]
fn cross_encoding_pipelining_on_one_connection_routes_correctly() {
    // One connection pipelines a binary sample, a JSON sample (same
    // seed), and a ping without reading. The ping answers first (it is
    // enqueued while the samples are still in flight); each sample
    // reply then self-identifies — `payload_bytes` means a counted
    // binary payload follows, inline `samples` means JSON rows — and
    // both decode to identical bits.
    use std::io::{BufRead, BufReader, Read, Write};
    let (gw, _pool) = gw_stack(1, CoordinatorConfig::default());
    let stream = std::net::TcpStream::connect(gw.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let req = |enc: &str| {
        format!(
            "{{\"op\":\"sample\",\"dataset\":\"gmm8\",\"n_samples\":32,\"seed\":7,\
             \"return_samples\":true,\"encoding\":\"{enc}\"}}\n"
        )
    };
    writer.write_all(req("bin").as_bytes()).unwrap();
    writer.write_all(req("json").as_bytes()).unwrap();
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    writer.flush().unwrap();

    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let pong = era_solver::json::parse(&line).unwrap();
    assert_eq!(pong.get("pong").as_bool(), Some(true), "ping must overtake the samples");

    let mut bin: Option<Tensor> = None;
    let mut json_t: Option<Tensor> = None;
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = era_solver::json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true), "{line}");
        let rows = j.get("rows").as_usize().unwrap();
        let dim = j.get("dim").as_usize().unwrap();
        if let Some(n) = j.get("payload_bytes").as_usize() {
            let mut bytes = vec![0u8; n];
            reader.read_exact(&mut bytes).unwrap();
            bin = Some(Tensor::from_le_bytes(&bytes, rows, dim).unwrap());
        } else {
            json_t = Some(era_solver::server::protocol::samples_from_json(&j).unwrap());
        }
    }
    let (bin, json_t) = (bin.expect("one binary reply"), json_t.expect("one JSON reply"));
    assert_eq!(bin.as_slice(), json_t.as_slice(), "same seed, same bits across encodings");
    gw.shutdown();
}
