//! Integration tests over the PJRT runtime + real AOT artifacts.
//!
//! These need `make artifacts` to have run; every test skips (returns
//! early) when `artifacts/manifest.json` is absent so `cargo test` still
//! passes on a fresh checkout.

use era_solver::metrics;
use era_solver::rng::Rng;
use era_solver::runtime::{Manifest, PjRtEngine, PjRtEps, TrainReport};
use era_solver::solvers::era::Selection;
use era_solver::solvers::eps_model::EpsModel;
use era_solver::solvers::schedule::{make_grid, GridKind};
use era_solver::solvers::{sample_with, SolverKind};
use era_solver::tensor::Tensor;

fn engine() -> Option<std::sync::Arc<PjRtEngine>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return None;
    }
    Some(std::sync::Arc::new(PjRtEngine::new("artifacts").expect("engine")))
}

#[test]
fn manifest_matches_rust_schedule_mirror() {
    let Some(eng) = engine() else { return };
    assert!(eng.manifest().schedule_probe_error() < 1e-5);
    // log_snr probe too: lambda(t) is half-logSNR, probe stores full.
    let m = eng.manifest();
    // Tolerance is loose at the t->0 end: the python probe computes
    // 1 - alpha_bar in f32 where alpha_bar ~ 1 - 5e-6 (catastrophic
    // cancellation costs ~1e-2 relative there); the rust mirror is f64.
    for (&t, &ls) in m.probe.t.iter().zip(&m.probe.log_snr) {
        let mine = 2.0 * m.schedule.lambda(t);
        assert!((mine - ls).abs() < 5e-3, "t={t}: {mine} vs {ls}");
    }
}

#[test]
fn eps_artifact_executes_all_buckets() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(0);
    for &bucket in &eng.manifest().batch_buckets.clone() {
        let x = rng.normal_tensor(bucket, 2);
        let t = vec![0.5f32; bucket];
        let out = eng.eval_eps("gmm8", &x, &t).expect("eval");
        assert_eq!((out.rows(), out.cols()), (bucket, 2));
        assert!(out.all_finite());
    }
}

#[test]
fn eps_padding_is_transparent() {
    // A 5-row batch must produce the same leading rows as the padded
    // 16-row bucket evaluated directly.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(1);
    let x16 = rng.normal_tensor(16, 2);
    let t16 = vec![0.3f32; 16];
    let full = eng.eval_eps("gmm8", &x16, &t16).unwrap();

    let x5 = x16.slice_rows(0, 5);
    let out5 = eng.eval_eps("gmm8", &x5, &t16[..5]).unwrap();
    assert_eq!(out5.rows(), 5);
    for r in 0..5 {
        for c in 0..2 {
            let a = out5.row(r)[c];
            let b = full.row(r)[c];
            assert!((a - b).abs() < 1e-5, "row {r} col {c}: {a} vs {b}");
        }
    }
}

#[test]
fn oversize_batch_splits() {
    let Some(eng) = engine() else { return };
    let top = *eng.manifest().batch_buckets.last().unwrap();
    let mut rng = Rng::new(2);
    let x = rng.normal_tensor(top + 7, 2);
    let t = vec![0.4f32; top + 7];
    let out = eng.eval_eps("gmm8", &x, &t).unwrap();
    assert_eq!(out.rows(), top + 7);
    assert!(out.all_finite());
}

#[test]
fn combine_artifact_matches_native_twin() {
    // The Pallas solver_combine artifact and Tensor::kernel_weighted_sum
    // are the same computation; pin them to each other through PJRT.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(3);
    let x = rng.normal_tensor(16, 2);
    let e1 = rng.normal_tensor(16, 2);
    let e2 = rng.normal_tensor(16, 2);
    let e3 = rng.normal_tensor(16, 2);
    let w = [0.8, -0.3, 0.5];
    let ab = (0.97, -0.12);

    let via_pjrt = eng.combine("gmm8", &[&e1, &e2, &e3], &w, &x, ab).unwrap();
    let native = Tensor::kernel_weighted_sum(&x, ab.0 as f32, ab.1 as f32, &[&e1, &e2, &e3], &w);
    assert_eq!(via_pjrt.rows(), 16);
    for (a, b) in via_pjrt.as_slice().iter().zip(native.as_slice()) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn trained_denoiser_is_gaussian_limit_at_t1() {
    // At t=1 the marginal is ~N(0, I) and the trained eps should roughly
    // reproduce the input (the identity on noise) — a loose sanity band.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(4);
    let x = rng.normal_tensor(64, 2);
    let t = vec![1.0f32; 64];
    let eps = eng.eval_eps("gmm8", &x, &t).unwrap();
    let rel = eps.mean_row_dist(&x) / x.mean_row_norm();
    assert!(rel < 0.5, "relative eps-vs-x deviation at t=1: {rel}");
}

#[test]
fn era_solver_samples_through_pjrt() {
    // Full L3->PJRT->L2/L1 path: ERA-Solver at NFE 10 on the trained
    // gmm8 denoiser must land near the reference moments.
    let Some(eng) = engine() else { return };
    let model = PjRtEps::new(&eng, "gmm8").unwrap();
    let sched = eng.manifest().schedule;
    let grid = make_grid(&sched, GridKind::LogSnr, 10, 1.0, 1e-3);
    let mut rng = Rng::new(5);
    let kind = SolverKind::Era { k: 4, selection: Selection::ErrorRobust { lambda: 15.0 } };
    let mut solver = kind.build(sched, grid, rng.normal_tensor(256, 2), 5, 10);
    let out = sample_with(&mut *solver, &model);
    assert!(out.all_finite());
    assert_eq!(model.eval_count(), 10);

    let entry = eng.dataset("gmm8").unwrap();
    let fid = metrics::fid(&out, &entry.ref_stats);
    assert!(fid < 1.0, "PJRT-backed ERA FID {fid} too high");
}

#[test]
fn executable_cache_compiles_once_per_bucket() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(6);
    let x = rng.normal_tensor(16, 2);
    let t = vec![0.5f32; 16];
    let _ = eng.eval_eps("gmm8", &x, &t).unwrap();
    let after_first = eng.compile_count();
    for _ in 0..3 {
        let _ = eng.eval_eps("gmm8", &x, &t).unwrap();
    }
    assert_eq!(eng.compile_count(), after_first, "recompiled a cached bucket");
}

#[test]
fn warmup_precompiles() {
    let Some(eng) = engine() else { return };
    eng.warmup("gmm8", &[1, 16]).unwrap();
    assert!(eng.compile_count() >= 2);
}

#[test]
fn train_report_error_curve_grows_toward_zero_t() {
    // The paper's Fig. 1 premise, measured on our actual trained model:
    // noise-estimation error increases as t -> 0.
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    for name in m.datasets.keys() {
        let rep = TrainReport::load("artifacts", name).unwrap();
        assert!(rep.error_curve.len() >= 8, "{name}: curve too short");
        let n = rep.error_curve.len();
        let lo_t: f64 = rep.error_curve[..n / 4].iter().map(|p| p.1).sum::<f64>() / (n / 4) as f64;
        let hi_t: f64 =
            rep.error_curve[3 * n / 4..].iter().map(|p| p.1).sum::<f64>() / (n - 3 * n / 4) as f64;
        assert!(
            lo_t > hi_t,
            "{name}: error at small t ({lo_t}) should exceed error at large t ({hi_t})"
        );
    }
}

#[test]
fn all_datasets_eval() {
    let Some(eng) = engine() else { return };
    let names: Vec<String> = eng.manifest().datasets.keys().cloned().collect();
    let mut rng = Rng::new(7);
    for name in names {
        let dim = eng.dataset(&name).unwrap().dim;
        let x = rng.normal_tensor(4, dim);
        let out = eng.eval_eps(&name, &x, &[0.7; 4]).unwrap();
        assert_eq!((out.rows(), out.cols()), (4, dim), "{name}");
        assert!(out.all_finite(), "{name}");
    }
}
