//! Cross-solver integration tests: the paper's qualitative claims, run
//! against the analytic GMM model (exact) and its error-injected wrapper
//! (the Fig. 1 premise), plus equal-NFE accounting across the whole
//! comparison set.

use era_solver::metrics::{self, Moments};
use era_solver::rng::Rng;
use era_solver::solvers::eps_model::{AnalyticGmm, CountingEps, EpsModel, NoisyEps};
use era_solver::solvers::schedule::{make_grid, GridKind, VpSchedule};
use era_solver::solvers::{sample_with, SolverKind};
use era_solver::tensor::Tensor;

fn reference() -> Moments {
    Moments::new(vec![0.0, 0.0], vec![2.0225, 0.0, 0.0, 2.0225])
}

fn run_fid(kind: &SolverKind, model: &dyn EpsModel, nfe: usize, grid: GridKind, n: usize) -> f64 {
    let sched = VpSchedule::default();
    let steps = kind.steps_for_nfe(nfe);
    let g = make_grid(&sched, grid, steps, 1.0, 1e-3);
    let mut rng = Rng::new(17);
    let mut solver = kind.build(sched, g, rng.normal_tensor(n, 2), 17, nfe);
    let out = sample_with(&mut *solver, model);
    assert!(out.all_finite(), "{} produced non-finite samples", kind.label());
    metrics::fid(&out, &reference())
}

#[test]
fn every_solver_spends_exactly_its_budget() {
    // Equal-NFE comparison only makes sense if the accounting is exact.
    let sched = VpSchedule::default();
    for (name, nfe) in [
        ("ddpm", 10),
        ("ddim", 10),
        ("iadams", 10),
        ("era", 10),
        ("era-fixed-4", 10),
        ("dpm-1", 10),
        ("dpm-2", 10),
        ("dpm-3", 10),
        ("dpm-fast", 10),
        ("pndm", 15),
        ("fon", 15),
    ] {
        let kind = SolverKind::parse(name).unwrap();
        let steps = kind.steps_for_nfe(nfe);
        let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
        let model = CountingEps::new(AnalyticGmm::gmm8(sched));
        let mut rng = Rng::new(0);
        let mut solver = kind.build(sched, grid, rng.normal_tensor(4, 2), 0, nfe);
        let _ = sample_with(&mut *solver, &model);
        let spent = model.calls();
        // PRK warmup solvers overshoot by at most 3 (their step quantum).
        let slack = if matches!(kind, SolverKind::Pndm | SolverKind::Fon) { 3 } else { 0 };
        assert!(
            spent >= nfe.saturating_sub(slack) && spent <= nfe + slack,
            "{name}: spent {spent} vs budget {nfe}"
        );
        assert_eq!(solver.nfe(), spent, "{name}: solver-side NFE accounting");
    }
}

#[test]
fn all_solvers_converge_with_exact_model_high_nfe() {
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    for name in ["ddim", "iadams", "era", "dpm-2", "dpm-fast", "pndm", "fon"] {
        let kind = SolverKind::parse(name).unwrap();
        let fid = run_fid(&kind, &model, 50, GridKind::Uniform, 2000);
        assert!(fid < 0.05, "{name}: FID {fid} at NFE 50");
    }
}

#[test]
fn era_wins_at_low_nfe_under_model_error() {
    // The paper's headline: at ~10 NFE with an imperfect model, ERA beats
    // DDIM and the traditional implicit-Adams PC.
    let sched = VpSchedule::default();
    let model = NoisyEps::new(AnalyticGmm::gmm8(sched), 1.0, 2.0, 23);
    let nfe = 10;
    let fid_era = run_fid(&SolverKind::parse("era").unwrap(), &model, nfe, GridKind::Uniform, 1500);
    let fid_ddim =
        run_fid(&SolverKind::parse("ddim").unwrap(), &model, nfe, GridKind::Uniform, 1500);
    let fid_ia =
        run_fid(&SolverKind::parse("iadams").unwrap(), &model, nfe, GridKind::Uniform, 1500);
    assert!(fid_era < fid_ddim, "era {fid_era} vs ddim {fid_ddim}");
    assert!(fid_era < fid_ia * 1.5, "era {fid_era} vs iadams {fid_ia}");
}

#[test]
fn ddim_monotone_improves_with_nfe() {
    // Tab. 1-3 structure: DDIM's FID falls as NFE grows.
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    let kind = SolverKind::parse("ddim").unwrap();
    let f10 = run_fid(&kind, &model, 10, GridKind::Uniform, 1500);
    let f50 = run_fid(&kind, &model, 50, GridKind::Uniform, 1500);
    assert!(f50 < f10, "ddim {f10} (10) -> {f50} (50)");
}

#[test]
fn logsnr_grid_beats_uniform_for_dpm_low_nfe() {
    // The paper follows DPM-Solver in using logSNR steps on CIFAR-10;
    // verify the grid actually helps the exponential-integrator solver.
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    let kind = SolverKind::parse("dpm-2").unwrap();
    let f_log = run_fid(&kind, &model, 10, GridKind::LogSnr, 1500);
    let f_uni = run_fid(&kind, &model, 10, GridKind::Uniform, 1500);
    assert!(f_log < f_uni, "logsnr {f_log} vs uniform {f_uni}");
}

#[test]
fn ddpm_needs_many_more_steps() {
    // Tab. 3's DDPM row: ancestral sampling is far off at low NFE.
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    // (On the 2-D GMM the gap is ~1.7x, far milder than the paper's
    // image-scale 278-vs-13 — but the ordering is the invariant.)
    let f_ddpm = run_fid(&SolverKind::parse("ddpm").unwrap(), &model, 10, GridKind::Uniform, 1500);
    let f_ddim = run_fid(&SolverKind::parse("ddim").unwrap(), &model, 10, GridKind::Uniform, 1500);
    assert!(f_ddpm > f_ddim, "ddpm {f_ddpm} vs ddim {f_ddim}");
}

#[test]
fn high_order_fixed_selection_detonates_ers_does_not() {
    // Tab. 4's signature blowup, as an integration-level guarantee.
    let sched = VpSchedule::default();
    let model = NoisyEps::new(AnalyticGmm::gmm8(sched), 1.5, 2.0, 5);
    let fid_fixed = run_fid(
        &SolverKind::parse("era-fixed-6").unwrap(),
        &model,
        15,
        GridKind::Uniform,
        1500,
    );
    let fid_ers =
        run_fid(&SolverKind::parse("era-6").unwrap(), &model, 15, GridKind::Uniform, 1500);
    assert!(
        fid_ers < fid_fixed / 2.0,
        "k=6: ERS {fid_ers} must be far below fixed {fid_fixed}"
    );
}

#[test]
fn era_robustness_margin_grows_with_error() {
    // Sweep error amplitude: ERA's advantage over DDIM should not shrink
    // as the injected error grows (the error-robustness claim).
    let sched = VpSchedule::default();
    let margin = |amp: f64| {
        let model = NoisyEps::new(AnalyticGmm::gmm8(sched), amp, 2.0, 13);
        let e = run_fid(&SolverKind::parse("era").unwrap(), &model, 10, GridKind::Uniform, 1200);
        let d = run_fid(&SolverKind::parse("ddim").unwrap(), &model, 10, GridKind::Uniform, 1200);
        d - e
    };
    let none = margin(0.0);
    let heavy = margin(1.5);
    assert!(heavy > none, "margin under error {heavy} vs clean {none}");
}

#[test]
fn solvers_deterministic_end_to_end() {
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    for name in ["era", "ddim", "dpm-fast", "iadams"] {
        let kind = SolverKind::parse(name).unwrap();
        let sched = VpSchedule::default();
        let grid = make_grid(&sched, GridKind::Uniform, kind.steps_for_nfe(12), 1.0, 1e-3);
        let mut rng1 = Rng::new(5);
        let mut s1 = kind.build(sched, grid.clone(), rng1.normal_tensor(32, 2), 5, 12);
        let mut rng2 = Rng::new(5);
        let mut s2 = kind.build(sched, grid, rng2.normal_tensor(32, 2), 5, 12);
        let a = sample_with(&mut *s1, &model);
        let b = sample_with(&mut *s2, &model);
        assert_eq!(a.as_slice(), b.as_slice(), "{name} nondeterministic");
    }
}

#[test]
fn t_end_choice_matters_near_zero() {
    // The paper evaluates both t_N = 1e-3 and 1e-4 on CIFAR-10; both must
    // run and produce finite, on-manifold output.
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    let sched = VpSchedule::default();
    for t_end in [1e-3, 1e-4] {
        let kind = SolverKind::parse("era").unwrap();
        let grid = make_grid(&sched, GridKind::LogSnr, 10, 1.0, t_end);
        let mut rng = Rng::new(3);
        let mut s = kind.build(sched, grid, rng.normal_tensor(500, 2), 3, 10);
        let out = sample_with(&mut *s, &model);
        let cov = metrics::mode_coverage(&out, &era_solver::data::gmm8_modes(), 0.5);
        assert!(cov > 0.9, "t_end {t_end}: coverage {cov}");
    }
}

#[test]
fn batched_rows_equal_unbatched_rows() {
    // Row independence: solving a 64-row batch must equal solving two
    // 32-row halves — the property the coordinator's cross-request
    // fusing relies on (the *model* is row-wise). Note ERA is excluded:
    // its Eq. 15 error measure is a batch mean, so rows within ONE
    // request are weakly coupled by design (as in the paper); the
    // coordinator never fuses solver state across requests, only model
    // evaluations, so this coupling stays request-local.
    let model = AnalyticGmm::gmm8(VpSchedule::default());
    let sched = VpSchedule::default();
    for name in ["ddim", "iadams", "dpm-fast"] {
        let kind = SolverKind::parse(name).unwrap();
        let mut rng = Rng::new(8);
        let x0 = rng.normal_tensor(64, 2);
        let grid = make_grid(&sched, GridKind::Uniform, kind.steps_for_nfe(10), 1.0, 1e-3);

        let mut s_full = kind.build(sched, grid.clone(), x0.clone(), 8, 10);
        let full = sample_with(&mut *s_full, &model);

        let mut parts = Vec::new();
        for half in 0..2 {
            let x = x0.slice_rows(half * 32, 32);
            let mut s = kind.build(sched, grid.clone(), x, 8, 10);
            parts.push(sample_with(&mut *s, &model));
        }
        let split = Tensor::vstack(&[&parts[0], &parts[1]]);
        for (a, b) in full.as_slice().iter().zip(split.as_slice()) {
            assert!((a - b).abs() < 1e-5, "{name} batch dependence: {a} vs {b}");
        }
    }
}
