//! Integration tests for the sharded worker pool: mid-trajectory
//! cancellation, deadlines, global admission control, linger-policy
//! fusion through the pool path, and wire-level cancel over TCP.
//!
//! A `PacedBank` adds a fixed latency per model evaluation (emulating a
//! device-bound denoiser) so requests are slow enough to cancel
//! mid-trajectory deterministically while tests stay fast.

use std::sync::Arc;
use std::time::Duration;

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, QosClass, RequestSpec, SubmitError};
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::server::client::Client;
use era_solver::server::{Server, ServerConfig};
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;
use era_solver::solvers::{EpsModel, TaskSpec};
use era_solver::tensor::Tensor;

/// A model bank with a fixed per-evaluation latency.
struct PacedBank {
    inner: MockBank,
    per_eval: Duration,
}

impl PacedBank {
    fn gmm8(per_eval: Duration) -> PacedBank {
        let sched = VpSchedule::default();
        PacedBank {
            inner: MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
            per_eval,
        }
    }
}

impl ModelBank for PacedBank {
    fn sched(&self) -> VpSchedule {
        self.inner.sched()
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        self.inner.dim(dataset)
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        std::thread::sleep(self.per_eval);
        self.inner.eval(dataset, x, t)
    }

    fn eval_cond(&self, dataset: &str, x: &Tensor, t: &[f32], c: &[f32]) -> Result<Tensor, String> {
        std::thread::sleep(self.per_eval);
        self.inner.eval_cond(dataset, x, t, c)
    }
}

fn paced_pool(per_eval_ms: u64, shards: usize, shard: CoordinatorConfig) -> WorkerPool {
    let bank: Arc<dyn ModelBank> =
        Arc::new(PacedBank::gmm8(Duration::from_millis(per_eval_ms)));
    WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard,
            max_inflight_rows: 0,
        },
    )
}

fn spec(n: usize, nfe: usize, seed: u64) -> RequestSpec {
    RequestSpec { n_samples: n, nfe, seed, ..Default::default() }
}

/// The acceptance scenario: a cancelled request retires early (NFE
/// consumed < budget) while a batch-mate on the same shard completes
/// unaffected (bit-identical to an undisturbed run).
#[test]
fn cancelled_request_retires_early_batchmates_unaffected() {
    let pool = paced_pool(10, 1, CoordinatorConfig::default());

    // Victim: a long trajectory we cancel a few rounds in.
    let victim = pool.submit(spec(8, 60, 1)).unwrap();
    // Batch-mate on the same (only) shard: short trajectory, runs in the
    // same fused slabs as the victim for its first rounds.
    let mate = pool.submit(spec(8, 10, 2)).unwrap();
    assert_eq!(victim.shard, mate.shard, "both must share the one shard");

    // Let a few evaluation rounds happen (poll rather than guess a
    // sleep so a loaded box cannot cancel before admission), then
    // cancel the victim.
    for _ in 0..400 {
        if pool.stats().evals() >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(pool.stats().evals() >= 2, "shard never started evaluating");
    victim.cancel();

    let v = victim.wait().unwrap();
    assert!(v.cancelled, "victim must report cancellation");
    assert!(v.nfe < 60, "victim consumed its whole budget ({} evals)", v.nfe);
    assert_eq!(v.samples.rows(), 8, "partial iterate still has the batch rows");
    assert!(v.samples.all_finite());

    let m = mate.wait().unwrap();
    assert!(!m.cancelled);
    assert_eq!(m.nfe, 10, "batch-mate must complete its full budget");
    assert_eq!(m.samples.rows(), 8);

    // The mate's result must be exactly what an undisturbed run yields.
    let solo = paced_pool(0, 1, CoordinatorConfig::default());
    let undisturbed = solo.sample(spec(8, 10, 2)).unwrap();
    assert_eq!(m.samples.as_slice(), undisturbed.samples.as_slice());
    solo.shutdown();

    let stats = pool.stats();
    assert_eq!(stats.cancelled(), 1);
    assert_eq!(stats.finished(), 1);
    pool.shutdown();
}

fn guided_spec(n: usize, nfe: usize, seed: u64, scale: f64) -> RequestSpec {
    RequestSpec {
        n_samples: n,
        nfe,
        seed,
        task: TaskSpec { guidance_scale: scale, guide_class: 2, ..Default::default() },
        ..Default::default()
    }
}

/// Workload acceptance scenario: cancelling a *guided* request
/// mid-trajectory (paired rows in every slab) leaves an unconditional
/// batch-mate bit-identical to a solo run, and admission accounting
/// drains back to zero.
#[test]
fn guided_cancel_leaves_unconditional_batchmates_bit_identical() {
    let pool = paced_pool(10, 1, CoordinatorConfig::default());

    // Victim: long guided trajectory (16 paired rows per step).
    let victim = pool.submit(guided_spec(8, 60, 1, 2.0)).unwrap();
    // Unconditional batch-mate sharing the shard's fused slabs.
    let mate = pool.submit(spec(8, 10, 2)).unwrap();
    assert_eq!(victim.shard, mate.shard);

    for _ in 0..400 {
        if pool.stats().evals() >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(pool.stats().evals() >= 2, "shard never started evaluating");
    victim.cancel();

    let v = victim.wait().unwrap();
    assert!(v.cancelled);
    assert!(v.nfe < 120, "guided victim consumed its whole paired budget ({})", v.nfe);
    assert_eq!(v.samples.rows(), 8, "partial iterate keeps sample rows, not paired rows");

    let m = mate.wait().unwrap();
    assert!(!m.cancelled);
    assert_eq!(m.nfe, 10);

    // Bit-identical to an undisturbed unconditional solo run.
    let solo = paced_pool(0, 1, CoordinatorConfig::default());
    let undisturbed = solo.sample(spec(8, 10, 2)).unwrap();
    assert_eq!(m.samples.as_slice(), undisturbed.samples.as_slice());
    solo.shutdown();

    let stats = pool.stats();
    assert_eq!(stats.cancelled(), 1);
    assert_eq!(stats.finished(), 1);
    assert_eq!(stats.workloads().0, 1, "one guided admission recorded");
    assert_eq!(stats.inflight_rows(), 0, "paired rows must drain from the gauges");
    pool.shutdown();
}

/// Admission control must charge guided requests as 2 rows per sample,
/// at both the shard gauge and the pool-wide cap.
#[test]
fn admission_cap_counts_guided_requests_as_double_rows() {
    let bank: Arc<dyn ModelBank> = Arc::new(PacedBank::gmm8(Duration::from_millis(10)));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards: 1,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 24,
        },
    );
    // Guided 8-sample request pins 16 rows.
    let first = pool.submit(guided_spec(8, 10, 1, 1.5)).unwrap();
    // A second guided request would need 16 more rows: 32 > 24 -> reject.
    match pool.submit(guided_spec(8, 10, 2, 1.5)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|t| t.shard)),
    }
    // A plain 8-row request fits exactly: 16 + 8 = 24.
    let second = pool.submit(spec(8, 10, 3)).unwrap();
    assert!(!first.wait().unwrap().cancelled);
    assert!(!second.wait().unwrap().cancelled);
    assert_eq!(pool.stats().pool_rejected, 1);
    pool.shutdown();
}

#[test]
fn stochastic_requests_are_shard_stable() {
    // The churn stream is owned per request: the same stochastic spec
    // must produce bit-identical samples through a multi-shard pool
    // (whatever placement/batching happened) as through a solo pool.
    let stochastic = RequestSpec {
        n_samples: 8,
        nfe: 12,
        seed: 5,
        task: TaskSpec { churn: 0.4, ..Default::default() },
        ..Default::default()
    };
    let pool = paced_pool(1, 2, CoordinatorConfig::default());
    // Load both shards so slabs genuinely mix.
    let noise: Vec<_> = (0..4).map(|i| pool.submit(spec(8, 12, 100 + i)).unwrap()).collect();
    let got = pool.sample(stochastic.clone()).unwrap();
    for t in noise {
        t.wait().unwrap();
    }
    assert_eq!(pool.stats().workloads().2, 1, "one stochastic admission recorded");
    pool.shutdown();

    let solo = paced_pool(0, 1, CoordinatorConfig::default());
    let want = solo.sample(stochastic).unwrap();
    solo.shutdown();
    assert_eq!(got.samples.as_slice(), want.samples.as_slice());
}

/// ISSUE 4 acceptance scenario: a request cancelled while one of its
/// slabs is physically in flight at an executor. The scheduler must
/// wait for the slab, drop the executor's output for the retired
/// request without delivering it, return the partial result, and leave
/// batch-mates bit-identical — with every gauge drained.
#[test]
fn cancel_while_slab_in_flight_drops_output_cleanly() {
    // Small slabs split the victim across two slabs per round; a paced
    // bank keeps each slab in flight long enough to cancel into the
    // window deterministically.
    let cfg = CoordinatorConfig {
        policy: BatchPolicy {
            max_rows: 8,
            min_rows: 1,
            max_wait: Duration::from_millis(0),
        },
        pipeline_depth: 2,
        ..Default::default()
    };
    let pool = paced_pool(30, 1, cfg);
    // Victim: 16 rows -> two 8-row slabs every round, long trajectory.
    let victim = pool.submit(spec(16, 60, 1)).unwrap();
    // Batch-mate in its own slab on the same shard.
    let mate = pool.submit(spec(8, 10, 2)).unwrap();
    assert_eq!(victim.shard, mate.shard);

    // Cancel while slabs are visibly in flight (the new gauge), not
    // between rounds.
    let mut saw_inflight = false;
    for _ in 0..600 {
        if pool.stats().inflight_slabs() >= 1 {
            saw_inflight = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(saw_inflight, "no slab ever showed as in flight");
    victim.cancel();

    let v = victim.wait().unwrap();
    assert!(v.cancelled, "victim must report cancellation");
    assert!(v.nfe < 60, "victim consumed its whole budget ({} evals)", v.nfe);
    assert_eq!(v.samples.rows(), 16, "partial iterate keeps the batch rows");
    assert!(v.samples.all_finite());

    let m = mate.wait().unwrap();
    assert!(!m.cancelled);
    assert_eq!(m.nfe, 10);

    // Bit-identical to an undisturbed solo run: the dropped executor
    // output never leaked into a batch-mate's slabs.
    let solo = paced_pool(0, 1, CoordinatorConfig::default());
    let undisturbed = solo.sample(spec(8, 10, 2)).unwrap();
    assert_eq!(m.samples.as_slice(), undisturbed.samples.as_slice());
    solo.shutdown();

    // The shard keeps serving after the mid-flight retirement, and
    // every gauge drains.
    let later = pool.sample(spec(4, 10, 3)).unwrap();
    assert_eq!(later.samples.rows(), 4);
    let stats = pool.stats();
    assert_eq!(stats.cancelled(), 1);
    assert_eq!(stats.finished(), 2);
    assert_eq!(stats.inflight_slabs(), 0, "slab gauge must drain");
    assert_eq!(stats.inflight_rows(), 0, "row gauge must drain");
    pool.shutdown();
}

/// The pipelined scheduler must overlap engine latency: 2 executors at
/// depth 2 finish a fixed one-slab-per-request workload materially
/// faster than the serialized depth-1 single-executor shard (the full
/// sweep + 1.3x CI gate live in benches/bench_pool.rs).
#[test]
fn pipelining_overlaps_engine_latency_smoke() {
    let run = |executors: usize, depth: usize| -> Duration {
        let cfg = CoordinatorConfig {
            policy: BatchPolicy {
                max_rows: 8,
                min_rows: 1,
                max_wait: Duration::from_millis(0),
            },
            executors_per_shard: executors,
            pipeline_depth: depth,
            ..Default::default()
        };
        let pool = paced_pool(4, 1, cfg);
        let t0 = std::time::Instant::now();
        let tickets: Vec<_> = (0..6).map(|i| pool.submit(spec(8, 10, i)).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let dt = t0.elapsed();
        pool.shutdown();
        dt
    };
    let serialized = run(1, 1);
    let pipelined = run(2, 2);
    // ~2x theoretical headroom; only guard against gross regression so
    // loaded CI boxes cannot flake this (the bench gate is the sharp
    // check).
    assert!(
        pipelined <= serialized,
        "pipelined shard ({pipelined:?}) slower than serialized ({serialized:?})"
    );
}

#[test]
fn deadline_expires_mid_trajectory() {
    let pool = paced_pool(10, 1, CoordinatorConfig::default());
    let mut s = spec(8, 60, 3);
    s.deadline_ms = Some(45);
    let res = pool.sample(s).unwrap();
    assert!(res.cancelled, "deadline must retire the request");
    // Typically a handful of evaluations happen before expiry; on a
    // stalled box it may be zero, but it can never reach the budget.
    assert!(res.nfe < 60, "nfe {} should be far below budget", res.nfe);
    pool.shutdown();
}

#[test]
fn queued_request_cancelled_before_admission_costs_nothing() {
    // One shard, one active slot: the second request waits in the queue
    // while the first runs; cancelling it there must cost zero evals.
    let cfg = CoordinatorConfig { max_active: 1, ..Default::default() };
    let pool = paced_pool(10, 1, cfg);
    let first = pool.submit(spec(8, 10, 1)).unwrap();
    let queued = pool.submit(spec(8, 10, 2)).unwrap();
    queued.cancel();
    let q = queued.wait().unwrap();
    assert!(q.cancelled);
    assert_eq!(q.nfe, 0);
    assert_eq!(q.samples.rows(), 0);
    assert!(!first.wait().unwrap().cancelled);
    pool.shutdown();
}

#[test]
fn global_admission_cap_rejects_and_recovers() {
    let bank: Arc<dyn ModelBank> = Arc::new(PacedBank::gmm8(Duration::from_millis(10)));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards: 2,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 8,
        },
    );
    let first = pool.submit(spec(8, 10, 1)).unwrap();
    // The gauge already carries 8 rows, so any further rows must bounce.
    match pool.submit(spec(8, 10, 2)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|t| t.shard)),
    }
    assert_eq!(pool.stats().pool_rejected, 1);
    first.wait().unwrap();
    // Load drained: admission opens again.
    assert!(pool.submit(spec(8, 10, 3)).is_ok());
    pool.shutdown();
}

/// A constant-eps denoiser: ERA's Lagrange prediction of a constant is
/// exact, so `delta_eps` collapses immediately — the canonical
/// converging workload for the QoS/adaptive-NFE paths.
struct ConstEps;

impl EpsModel for ConstEps {
    fn eval(&self, x: &Tensor, _t: &[f32]) -> Tensor {
        Tensor::from_vec(vec![0.25; x.rows() * x.cols()], x.rows(), x.cols())
    }

    fn dim(&self) -> usize {
        2
    }
}

fn paced_const_pool(per_eval_ms: u64, max_inflight_rows: usize) -> WorkerPool {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> = Arc::new(PacedBank {
        inner: MockBank::new(sched).with("const", Box::new(ConstEps)),
        per_eval: Duration::from_millis(per_eval_ms),
    });
    WorkerPool::start(
        bank,
        PoolConfig {
            shards: 1,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows,
        },
    )
}

fn qos_spec(qos: QosClass, n: usize, seed: u64) -> RequestSpec {
    RequestSpec {
        dataset: "const".into(),
        solver: "era".into(),
        n_samples: n,
        nfe: 24,
        seed,
        qos,
        ..Default::default()
    }
}

/// QoS over-cap acceptance scenario (DESIGN.md §12): at the global row
/// cap a strict request is rejected outright, while a besteffort
/// request squeezes in on its floor charge, is latched degraded,
/// completes at the era NFE floor with the early-stop marker, and the
/// new counters surface in the Prometheus page and the stats JSON.
#[test]
fn over_cap_besteffort_degrades_to_floor_while_strict_rejects() {
    let pool = paced_const_pool(10, 12);

    // Pins 8 of the 12-row cap for ~240ms (24 paced evaluations).
    let strict = pool.submit(qos_spec(QosClass::Strict, 8, 1)).unwrap();

    // A second strict 8-row request pays worst case: 16 > 12 -> reject.
    match pool.submit(qos_spec(QosClass::Strict, 8, 2)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|t| t.shard)),
    }

    // Besteffort is charged its floor (ceil(8*4/24) = 2 rows): 10 <= 12
    // fits, but its worst case (16 > 12) does not -> admitted degraded.
    let best = pool.submit(qos_spec(QosClass::BestEffort, 8, 3)).unwrap();

    let b = best.wait().unwrap();
    assert!(!b.cancelled);
    assert!(b.early_stop, "degraded besteffort must carry the early-stop marker");
    assert_eq!(b.nfe, 4, "degraded besteffort retires at the era NFE floor, got {}", b.nfe);
    assert_eq!(b.samples.rows(), 8);
    assert!(b.samples.all_finite());

    let s = strict.wait().unwrap();
    assert!(!s.cancelled && !s.early_stop);
    assert_eq!(s.nfe, 24, "strict keeps its full fixed budget");

    let stats = pool.stats();
    assert_eq!(stats.pool_rejected, 1);
    assert_eq!(stats.finished(), 2);
    assert_eq!(stats.early_stops(), 1);
    assert_eq!(stats.degraded_requests(), 1);
    assert_eq!(stats.inflight_rows(), 0, "admission gauges must drain");

    let prom = stats.prometheus();
    assert!(prom.contains("era_early_stops_total 1\n"), "{prom}");
    assert!(prom.contains("era_degraded_requests_total 1\n"), "{prom}");
    assert!(prom.contains("era_delivered_nfe_requests_total{nfe=\"4\"} 1\n"), "{prom}");
    assert!(prom.contains("era_delivered_nfe_requests_total{nfe=\"32\"} 1\n"), "{prom}");

    let json = stats.to_json();
    assert_eq!(json.get("early_stops").as_usize(), Some(1));
    assert_eq!(json.get("degraded_requests").as_usize(), Some(1));
    let hist = json.get("delivered_nfe_hist").as_arr().expect("hist array");
    let total: f64 = hist.iter().filter_map(|v| v.as_f64()).sum();
    assert_eq!(total as u64, 2, "both deliveries observed in the NFE histogram");
    pool.shutdown();
}

#[test]
fn linger_policy_fuses_across_requests_through_the_pool() {
    // Mirrors the coordinator's fusion test but through the pool path:
    // 8 concurrent 16-row requests under a min_rows=64 linger policy
    // must fuse into large slabs on the one shard.
    let cfg = CoordinatorConfig {
        policy: BatchPolicy {
            max_rows: 256,
            min_rows: 64,
            max_wait: Duration::from_millis(30),
        },
        ..Default::default()
    };
    let pool = paced_pool(0, 1, cfg);
    let tickets: Vec<_> = (0..8).map(|i| pool.submit(spec(16, 10, i)).unwrap()).collect();
    for t in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.samples.rows(), 16);
    }
    let stats = pool.stats();
    assert!(stats.evals() < 80, "no fusion happened: {} evals", stats.evals());
    assert!(stats.occupancy() > 16.0, "occupancy {}", stats.occupancy());
    pool.shutdown();
}

#[test]
fn throughput_scales_with_shards_on_a_paced_bank() {
    // Smoke-level scaling check (the full sweep lives in
    // benches/bench_pool.rs): with a per-eval latency dominating, four
    // shards must finish a fixed workload materially faster than one.
    let run = |shards: usize| -> Duration {
        let pool = paced_pool(5, shards, CoordinatorConfig::default());
        let t0 = std::time::Instant::now();
        let tickets: Vec<_> =
            (0..8).map(|i| pool.submit(spec(4, 10, i)).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let dt = t0.elapsed();
        pool.shutdown();
        dt
    };
    let t1 = run(1);
    let t4 = run(4);
    // One shard fuses everything into ~10 rounds of 5ms; four shards
    // run ~10 rounds each in parallel over 2 requests apiece. Wall time
    // must not degrade; allow generous scheduler noise.
    assert!(
        t4 <= t1 * 3,
        "4 shards ({t4:?}) dramatically slower than 1 shard ({t1:?})"
    );
}

#[test]
fn wire_level_cancel_from_second_connection() {
    let bank: Arc<dyn ModelBank> = Arc::new(PacedBank::gmm8(Duration::from_millis(10)));
    let pool = Arc::new(WorkerPool::start(bank, PoolConfig::default()));
    let server = Server::start(pool.clone(), ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    let submitter = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.sample_tagged(&spec(8, 60, 1), Some(77)).unwrap()
    });

    // Second connection cancels the tagged request once it is visibly
    // in flight (poll stats rather than guessing a sleep).
    let mut c2 = Client::connect(addr).unwrap();
    let mut cancelled = false;
    for _ in 0..200 {
        let stats = c2.stats().unwrap();
        if stats.get("admitted").as_usize() == Some(1) {
            cancelled = c2.cancel(77).unwrap();
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(cancelled, "tag 77 was never cancellable");

    let outcome = submitter.join().expect("submitter thread");
    assert!(outcome.cancelled);
    assert!(outcome.nfe < 60, "nfe {} should be below budget", outcome.nfe);
    // The registry forgets the tag once the request is done.
    assert!(!c2.cancel(77).unwrap());
    server.shutdown();
}
