//! Integration tests over the TCP serving path: real sockets, real
//! threads, the mock model bank (no artifacts needed so these always
//! run), plus one full-stack PJRT test when artifacts exist. The server
//! fronts a [`WorkerPool`]; a one-shard pool reproduces the old bare
//! coordinator behaviour exactly.

use std::sync::Arc;
use std::time::Duration;

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, RequestSpec};
use era_solver::metrics;
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::server::client::{generate_load, Client};
use era_solver::server::{Server, ServerConfig};
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;

fn mock_pool_stack(shards: usize, config: CoordinatorConfig) -> (Server, Arc<WorkerPool>) {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> =
        Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
    let pool = Arc::new(WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard: config,
            max_inflight_rows: 0,
        },
    ));
    let server = Server::start(pool.clone(), ServerConfig::default()).expect("bind");
    (server, pool)
}

fn mock_stack(config: CoordinatorConfig) -> (Server, Arc<WorkerPool>) {
    mock_pool_stack(1, config)
}

fn spec(n: usize, seed: u64) -> RequestSpec {
    RequestSpec { n_samples: n, seed, ..Default::default() }
}

#[test]
fn ping_and_stats_roundtrip() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.ping().unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("finished").as_usize(), Some(0));
    server.shutdown();
}

#[test]
fn sample_over_the_wire_is_on_manifold() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let (samples, secs) = c.sample(&spec(300, 4)).unwrap();
    assert_eq!((samples.rows(), samples.cols()), (300, 2));
    assert!(secs >= 0.0);
    let cov = metrics::mode_coverage(&samples, &era_solver::data::gmm8_modes(), 0.5);
    assert!(cov > 0.9, "coverage {cov}");
    server.shutdown();
}

#[test]
fn workload_fields_roundtrip_over_the_wire() {
    // Guided + img2img + stochastic requests through the real TCP path:
    // the client serialises the task fields (including the init row
    // payload) and the result matches the in-process equivalent bitwise.
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();

    let mut rng = era_solver::rng::Rng::new(77);
    let init = rng.normal_tensor(8, 2);
    let wire_spec = RequestSpec {
        n_samples: 8,
        nfe: 12,
        seed: 3,
        task: era_solver::solvers::TaskSpec {
            guidance_scale: 1.5,
            guide_class: 4,
            strength: 0.5,
            init: Some(init),
            churn: 0.3,
        },
        ..Default::default()
    };
    let (samples, _) = c.sample(&wire_spec).unwrap();
    assert_eq!((samples.rows(), samples.cols()), (8, 2));
    assert!(samples.all_finite());

    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let mut direct = wire_spec.build_solver(sched, 2).unwrap();
    let want = era_solver::solvers::sample_with(&mut *direct, &model);
    assert_eq!(samples.as_slice(), want.as_slice());
    server.shutdown();
}

#[test]
fn malformed_lines_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for bad in ["not json", "{\"op\":\"nope\"}", "{\"op\":\"sample\",\"solver\":\"wat\"}"] {
        writeln!(writer, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = era_solver::json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false), "line: {bad}");
        assert!(j.get("error").as_str().is_some());
    }
    server.shutdown();
}

#[test]
fn concurrent_clients_all_served() {
    let cfg = CoordinatorConfig {
        max_active: 16,
        queue_capacity: 64,
        policy: BatchPolicy {
            max_rows: 256,
            min_rows: 32,
            max_wait: Duration::from_millis(5),
        },
        ..Default::default()
    };
    let (server, pool) = mock_stack(cfg);
    let report = generate_load(server.local_addr(), &spec(32, 0), 6, 4);
    assert_eq!(report.errors, 0, "all requests should succeed");
    assert_eq!(report.requests, 24);
    assert!(report.throughput_rows > 0.0);
    // Cross-request fusion must have happened under this load.
    assert!(
        pool.stats().occupancy() > 32.0,
        "occupancy {}",
        pool.stats().occupancy()
    );
    server.shutdown();
}

#[test]
fn per_request_solver_and_nfe_respected() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    for (solver, nfe) in [("ddim", 8), ("era-3@5", 12), ("dpm-fast", 9)] {
        let mut s = spec(16, 2);
        s.solver = solver.into();
        s.nfe = nfe;
        let (samples, _) = c.sample(&s).unwrap();
        assert_eq!(samples.rows(), 16, "{solver}");
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("finished").as_usize(), Some(3));
    server.shutdown();
}

#[test]
fn invalid_request_over_wire_errors_cleanly() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut s = spec(8, 0);
    s.dataset = "missing".into();
    assert!(c.sample(&s).is_err());
    // Connection still usable afterwards.
    c.ping().unwrap();
    server.shutdown();
}

#[test]
fn server_survives_client_disconnect_mid_session() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    {
        let mut c = Client::connect(server.local_addr()).unwrap();
        c.ping().unwrap();
        // drop without closing politely
    }
    let mut c2 = Client::connect(server.local_addr()).unwrap();
    let (samples, _) = c2.sample(&spec(8, 1)).unwrap();
    assert_eq!(samples.rows(), 8);
    server.shutdown();
}

#[test]
fn stats_report_pool_shape() {
    let shard = CoordinatorConfig {
        executors_per_shard: 2,
        pipeline_depth: 2,
        ..Default::default()
    };
    let (server, _pool) = mock_pool_stack(2, shard);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let (samples, _) = c.sample(&spec(16, 5)).unwrap();
    assert_eq!(samples.rows(), 16);
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("shards").as_usize(), Some(2));
    assert_eq!(stats.get("finished").as_usize(), Some(1));
    // The pipeline shape and executor telemetry ride the same response.
    assert_eq!(stats.get("executors_per_shard").as_usize(), Some(2));
    assert_eq!(stats.get("pipeline_depth").as_usize(), Some(2));
    assert_eq!(stats.get("inflight_slabs").as_usize(), Some(0));
    assert!(stats.get("executor_busy_frac").as_f64().is_some());
    let shards = c.shards().unwrap();
    assert_eq!(shards.get("shards").as_usize(), Some(2));
    assert_eq!(shards.get("per_shard").as_arr().map(|a| a.len()), Some(2));
    let per_shard = shards.get("per_shard").as_arr().unwrap();
    assert_eq!(per_shard[0].get("inflight_slabs").as_usize(), Some(0));
    assert!(per_shard[0].get("depth_hist").as_arr().is_some());
    server.shutdown();
}

#[test]
fn cancel_of_unknown_tag_is_false() {
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    assert!(!c.cancel(12345).unwrap());
    server.shutdown();
}

#[test]
fn deadline_zero_round_trips_as_cancelled() {
    // deadline_ms=0 expires before admission: the wire response must be
    // ok:true, cancelled:true, nfe 0, zero rows.
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut s = spec(32, 1);
    s.deadline_ms = Some(0);
    let out = c.sample_tagged(&s, None).unwrap();
    assert!(out.cancelled);
    assert_eq!(out.nfe, 0);
    assert_eq!(out.samples.rows(), 0);
    // Connection still serves normal requests afterwards.
    let (samples, _) = c.sample(&spec(8, 2)).unwrap();
    assert_eq!(samples.rows(), 8);
    server.shutdown();
}

#[test]
fn full_stack_pjrt_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let engine = Arc::new(era_solver::runtime::PjRtEngine::new("artifacts").unwrap());
    let entry = engine.dataset("gmm8").unwrap().clone();
    let bank: Arc<dyn ModelBank> = engine;
    let pool = Arc::new(WorkerPool::start(bank, PoolConfig::default()));
    let server = Server::start(pool.clone(), ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut s = spec(256, 3);
    s.grid = "logsnr".into();
    let (samples, _) = c.sample(&s).unwrap();
    let fid = metrics::fid(&samples, &entry.ref_stats);
    assert!(fid < 1.0, "PJRT-served FID {fid}");
    server.shutdown();
}
