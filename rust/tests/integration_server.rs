//! Integration tests over the TCP serving path: real sockets, real
//! threads, the mock model bank (no artifacts needed so these always
//! run), plus one full-stack PJRT test when artifacts exist. The server
//! fronts a [`WorkerPool`]; a one-shard pool reproduces the old bare
//! coordinator behaviour exactly.

use std::sync::Arc;
use std::time::Duration;

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, RequestSpec};
use era_solver::metrics;
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::server::client::{generate_load, Client};
use era_solver::server::{Server, ServerConfig};
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;

fn mock_pool_stack(shards: usize, config: CoordinatorConfig) -> (Server, Arc<WorkerPool>) {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> =
        Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
    let pool = Arc::new(WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard: config,
            max_inflight_rows: 0,
        },
    ));
    let server = Server::start(pool.clone(), ServerConfig::default()).expect("bind");
    (server, pool)
}

fn mock_stack(config: CoordinatorConfig) -> (Server, Arc<WorkerPool>) {
    mock_pool_stack(1, config)
}

fn spec(n: usize, seed: u64) -> RequestSpec {
    RequestSpec { n_samples: n, seed, ..Default::default() }
}

#[test]
fn ping_and_stats_roundtrip() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.ping().unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("finished").as_usize(), Some(0));
    server.shutdown();
}

#[test]
fn sample_over_the_wire_is_on_manifold() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let (samples, secs) = c.sample(&spec(300, 4)).unwrap();
    assert_eq!((samples.rows(), samples.cols()), (300, 2));
    assert!(secs >= 0.0);
    let cov = metrics::mode_coverage(&samples, &era_solver::data::gmm8_modes(), 0.5);
    assert!(cov > 0.9, "coverage {cov}");
    server.shutdown();
}

#[test]
fn workload_fields_roundtrip_over_the_wire() {
    // Guided + img2img + stochastic requests through the real TCP path:
    // the client serialises the task fields (including the init row
    // payload) and the result matches the in-process equivalent bitwise.
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();

    let mut rng = era_solver::rng::Rng::new(77);
    let init = rng.normal_tensor(8, 2);
    let wire_spec = RequestSpec {
        n_samples: 8,
        nfe: 12,
        seed: 3,
        task: era_solver::solvers::TaskSpec {
            guidance_scale: 1.5,
            guide_class: 4,
            strength: 0.5,
            init: Some(init),
            churn: 0.3,
        },
        ..Default::default()
    };
    let (samples, _) = c.sample(&wire_spec).unwrap();
    assert_eq!((samples.rows(), samples.cols()), (8, 2));
    assert!(samples.all_finite());

    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let mut direct = wire_spec.build_solver(sched, 2).unwrap();
    let want = era_solver::solvers::sample_with(&mut *direct, &model);
    assert_eq!(samples.as_slice(), want.as_slice());
    server.shutdown();
}

#[test]
fn malformed_lines_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for bad in ["not json", "{\"op\":\"nope\"}", "{\"op\":\"sample\",\"solver\":\"wat\"}"] {
        writeln!(writer, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = era_solver::json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false), "line: {bad}");
        assert!(j.get("error").as_str().is_some());
    }
    server.shutdown();
}

#[test]
fn concurrent_clients_all_served() {
    let cfg = CoordinatorConfig {
        max_active: 16,
        queue_capacity: 64,
        policy: BatchPolicy {
            max_rows: 256,
            min_rows: 32,
            max_wait: Duration::from_millis(5),
        },
        ..Default::default()
    };
    let (server, pool) = mock_stack(cfg);
    let report = generate_load(server.local_addr(), &spec(32, 0), 6, 4);
    assert_eq!(report.errors, 0, "all requests should succeed");
    assert_eq!(report.requests, 24);
    assert!(report.throughput_rows > 0.0);
    // Cross-request fusion must have happened under this load.
    assert!(
        pool.stats().occupancy() > 32.0,
        "occupancy {}",
        pool.stats().occupancy()
    );
    server.shutdown();
}

#[test]
fn per_request_solver_and_nfe_respected() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    for (solver, nfe) in [("ddim", 8), ("era-3@5", 12), ("dpm-fast", 9)] {
        let mut s = spec(16, 2);
        s.solver = solver.into();
        s.nfe = nfe;
        let (samples, _) = c.sample(&s).unwrap();
        assert_eq!(samples.rows(), 16, "{solver}");
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("finished").as_usize(), Some(3));
    server.shutdown();
}

#[test]
fn invalid_request_over_wire_errors_cleanly() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut s = spec(8, 0);
    s.dataset = "missing".into();
    assert!(c.sample(&s).is_err());
    // Connection still usable afterwards.
    c.ping().unwrap();
    server.shutdown();
}

#[test]
fn server_survives_client_disconnect_mid_session() {
    let (server, _coord) = mock_stack(CoordinatorConfig::default());
    {
        let mut c = Client::connect(server.local_addr()).unwrap();
        c.ping().unwrap();
        // drop without closing politely
    }
    let mut c2 = Client::connect(server.local_addr()).unwrap();
    let (samples, _) = c2.sample(&spec(8, 1)).unwrap();
    assert_eq!(samples.rows(), 8);
    server.shutdown();
}

#[test]
fn stats_report_pool_shape() {
    let shard = CoordinatorConfig {
        executors_per_shard: 2,
        pipeline_depth: 2,
        ..Default::default()
    };
    let (server, _pool) = mock_pool_stack(2, shard);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let (samples, _) = c.sample(&spec(16, 5)).unwrap();
    assert_eq!(samples.rows(), 16);
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("shards").as_usize(), Some(2));
    assert_eq!(stats.get("finished").as_usize(), Some(1));
    // The pipeline shape and executor telemetry ride the same response.
    assert_eq!(stats.get("executors_per_shard").as_usize(), Some(2));
    assert_eq!(stats.get("pipeline_depth").as_usize(), Some(2));
    assert_eq!(stats.get("inflight_slabs").as_usize(), Some(0));
    assert!(stats.get("executor_busy_frac").as_f64().is_some());
    let shards = c.shards().unwrap();
    assert_eq!(shards.get("shards").as_usize(), Some(2));
    assert_eq!(shards.get("per_shard").as_arr().map(|a| a.len()), Some(2));
    let per_shard = shards.get("per_shard").as_arr().unwrap();
    assert_eq!(per_shard[0].get("inflight_slabs").as_usize(), Some(0));
    assert!(per_shard[0].get("depth_hist").as_arr().is_some());
    server.shutdown();
}

#[test]
fn cancel_of_unknown_tag_is_false() {
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    assert!(!c.cancel(12345).unwrap());
    server.shutdown();
}

#[test]
fn deadline_zero_round_trips_as_cancelled() {
    // deadline_ms=0 expires before admission: the wire response must be
    // ok:true, cancelled:true, nfe 0, zero rows.
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut s = spec(32, 1);
    s.deadline_ms = Some(0);
    let out = c.sample_tagged(&s, None).unwrap();
    assert!(out.cancelled);
    assert_eq!(out.nfe, 0);
    assert_eq!(out.samples.rows(), 0);
    // Connection still serves normal requests afterwards.
    let (samples, _) = c.sample(&spec(8, 2)).unwrap();
    assert_eq!(samples.rows(), 8);
    server.shutdown();
}

#[test]
fn metrics_op_returns_prometheus_text() {
    let (server, _pool) = mock_pool_stack(2, CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let (samples, _) = c.sample(&spec(24, 9)).unwrap();
    assert_eq!(samples.rows(), 24);
    let text = c.metrics().unwrap();
    assert!(text.contains("# HELP era_requests_finished_total"));
    assert!(text.contains("# TYPE era_requests_finished_total counter"));
    assert!(text.contains("era_requests_finished_total 1"));
    assert!(text.contains("era_shards 2"));
    // Per-stage latency histograms, one family labelled by stage, with
    // cumulative buckets up to +Inf.
    for stage in ["queue", "solver_step", "eval", "finalize"] {
        assert!(
            text.contains(&format!("era_stage_latency_seconds_bucket{{stage=\"{stage}\",le=\"+Inf\"}}")),
            "missing stage {stage} in:\n{text}"
        );
    }
    // The finished request passed through the solver-step stage at least
    // once, so its histogram count is non-zero.
    assert!(text.contains("era_stage_latency_seconds_count{stage=\"solver_step\"}"));
    server.shutdown();
}

#[test]
fn trace_op_dumps_request_spans_across_shards() {
    // Tagged requests through a 2-shard pool: each tag resolves to its
    // owning shard's flight recorder, and the dumped trace is a complete
    // admitted→finalize lifecycle.
    let (server, _pool) = mock_pool_stack(2, CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    for tag in [501u64, 502] {
        let mut s = spec(16, tag);
        s.solver = "era".into();
        s.nfe = 10;
        let out = c.sample_tagged(&s, Some(tag)).unwrap();
        assert!(!out.cancelled);
        let trace = c.trace(tag).unwrap();
        assert_eq!(trace.get("tag").as_usize(), Some(tag as usize));
        assert!(trace.get("shard").as_usize().is_some());
        let events = trace.get("events").as_arr().expect("events array");
        assert!(!events.is_empty(), "tag {tag} trace empty");
        let kinds: Vec<&str> = events
            .iter()
            .map(|e| e.get("kind").as_str().unwrap())
            .collect();
        assert_eq!(kinds.first(), Some(&"admitted"));
        assert_eq!(kinds.last(), Some(&"finalize"));
        for needed in ["lane_attach", "queue_wait", "solver_step", "slab_dispatch", "slab_complete", "era_step"] {
            assert!(kinds.contains(&needed), "tag {tag} missing {needed}: {kinds:?}");
        }
        // Timestamps are nondecreasing within the trace.
        let ts: Vec<f64> = events.iter().map(|e| e.get("at_ns").as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }
    server.shutdown();
}

#[test]
fn trace_of_cancelled_request_ends_at_cancel() {
    // A request parked behind a huge min_rows batch policy gets
    // cancelled by tag from a second connection; its wire trace must be
    // terminal at the cancel event with nothing recorded after it.
    let cfg = CoordinatorConfig {
        policy: BatchPolicy {
            max_rows: 8192,
            min_rows: 4096,
            max_wait: Duration::from_secs(5),
        },
        ..Default::default()
    };
    let (server, _pool) = mock_stack(cfg);
    let addr = server.local_addr();
    let tag = 9001u64;
    let submitter = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.sample_tagged(&spec(16, 1), Some(tag)).unwrap()
    });
    let mut c2 = Client::connect(addr).unwrap();
    // Wait for the tag to register, then cancel it.
    let mut cancelled = false;
    for _ in 0..500 {
        if c2.cancel(tag).unwrap() {
            cancelled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(cancelled, "tag never registered");
    let out = submitter.join().unwrap();
    assert!(out.cancelled);
    let trace = c2.trace(tag).unwrap();
    let events = trace.get("events").as_arr().unwrap();
    let kinds: Vec<&str> = events.iter().map(|e| e.get("kind").as_str().unwrap()).collect();
    assert_eq!(kinds.last(), Some(&"cancelled"), "kinds: {kinds:?}");
    assert_eq!(kinds.iter().filter(|k| **k == "cancelled").count(), 1);
    server.shutdown();
}

#[test]
fn trace_of_unknown_tag_errors() {
    let (server, _pool) = mock_stack(CoordinatorConfig::default());
    let mut c = Client::connect(server.local_addr()).unwrap();
    let err = c.trace(424242).unwrap_err();
    assert!(err.contains("unknown trace tag"), "err: {err}");
    server.shutdown();
}

#[test]
fn full_stack_pjrt_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let engine = Arc::new(era_solver::runtime::PjRtEngine::new("artifacts").unwrap());
    let entry = engine.dataset("gmm8").unwrap().clone();
    let bank: Arc<dyn ModelBank> = engine;
    let pool = Arc::new(WorkerPool::start(bank, PoolConfig::default()));
    let server = Server::start(pool.clone(), ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    let mut s = spec(256, 3);
    s.grid = "logsnr".into();
    let (samples, _) = c.sample(&s).unwrap();
    let fid = metrics::fid(&samples, &entry.ref_stats);
    assert!(fid < 1.0, "PJRT-served FID {fid}");
    server.shutdown();
}
