//! Connection-scaling bench: the epoll gateway vs the blocking
//! thread-per-connection server, same wire protocol, same pool, same
//! closed-loop load — but the gateway carries **4x the held-open
//! connection count** while it serves.
//!
//! Each phase opens a herd of idle keep-alive connections (pinged once
//! so the accept has completed), then runs the load generator twice and
//! keeps the better p99 (shared CI runners are noisy). CI gates
//! (ISSUE: readiness gateway):
//!
//! - `conn_ratio`  — gateway held connections / legacy held connections,
//!   4.0 by construction; regresses if the gateway cannot even hold them.
//! - `p99_parity`  — legacy p99 / gateway p99 at that 4x count; >= 0.5
//!   means the gateway's p99 is no worse than 2x the legacy server's
//!   while multiplexing 4x the connections on 2 io threads.
//! - `errors`      — total failed requests across both phases; must be 0.
//! - `gauge_ok`    — 1.0 when `open_connections` telemetry saw the
//!   whole gateway herd.
//!
//! Absolute p99s ride along uncommitted for trend tracking.
//!
//! ```text
//! cargo bench --bench bench_gateway               # 60 vs 240 conns
//! ERA_BENCH_QUICK=1 cargo bench --bench bench_gateway   # 25 vs 100
//! ```

#[cfg(not(target_os = "linux"))]
fn main() {
    println!("bench_gateway: skipped (the readiness gateway requires Linux epoll)");
}

#[cfg(target_os = "linux")]
fn main() {
    linux::run();
}

#[cfg(target_os = "linux")]
mod linux {
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::Arc;
    use std::time::Duration;

    use era_solver::coordinator::service::{MockBank, ModelBank};
    use era_solver::coordinator::{CoordinatorConfig, RequestSpec};
    use era_solver::obs::{BenchReport, Direction};
    use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
    use era_solver::server::client::{generate_load, LoadReport};
    use era_solver::server::gateway::{Gateway, GatewayConfig};
    use era_solver::server::{Server, ServerConfig};
    use era_solver::solvers::eps_model::AnalyticGmm;
    use era_solver::solvers::schedule::VpSchedule;
    use era_solver::tensor::Tensor;

    /// MockBank wrapper with a fixed latency per evaluation — a stable
    /// per-request service-time floor (NFE x 1ms) so the p99s being
    /// compared are dominated by serving behaviour, not by noise around
    /// a microsecond-scale analytic eval.
    struct LatencyBank {
        inner: MockBank,
        per_eval: Duration,
    }

    impl ModelBank for LatencyBank {
        fn sched(&self) -> VpSchedule {
            self.inner.sched()
        }

        fn dim(&self, dataset: &str) -> Result<usize, String> {
            self.inner.dim(dataset)
        }

        fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
            std::thread::sleep(self.per_eval);
            self.inner.eval(dataset, x, t)
        }
    }

    const NFE: usize = 5;
    const ROWS: usize = 8;
    const WORKERS: usize = 4;
    const REQUESTS_PER_WORKER: usize = 5;

    fn pool() -> Arc<WorkerPool> {
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> = Arc::new(LatencyBank {
            inner: MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
            per_eval: Duration::from_millis(1),
        });
        Arc::new(WorkerPool::start(
            bank,
            PoolConfig {
                shards: 1,
                placement: PlacementPolicy::RoundRobin,
                shard: CoordinatorConfig::default(),
                max_inflight_rows: 0,
            },
        ))
    }

    fn spec() -> RequestSpec {
        RequestSpec { n_samples: ROWS, nfe: NFE, ..Default::default() }
    }

    /// Open `n` keep-alive connections, ping each once (so the accept
    /// and session installation have completed), and hold the raw
    /// streams open. One fd per connection on each side.
    fn hold_idle(addr: SocketAddr, n: usize) -> Vec<TcpStream> {
        let mut held = Vec::with_capacity(n);
        for i in 0..n {
            let mut s = TcpStream::connect(addr)
                .unwrap_or_else(|e| panic!("idle connect {i}/{n}: {e}"));
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut got = Vec::new();
            let mut buf = [0u8; 256];
            loop {
                let k = s.read(&mut buf).unwrap_or_else(|e| panic!("idle ping {i}: {e}"));
                assert!(k > 0, "server closed idle connection {i} of {n}");
                got.extend_from_slice(&buf[..k]);
                if got.contains(&b'\n') {
                    break;
                }
            }
            held.push(s);
        }
        held
    }

    /// Run the closed loop twice against `addr` and keep the run with
    /// the better p99 (errors are summed — a retry must not hide them).
    fn best_of_two(addr: SocketAddr) -> (LoadReport, usize) {
        let a = generate_load(addr, &spec(), WORKERS, REQUESTS_PER_WORKER);
        let b = generate_load(addr, &spec(), WORKERS, REQUESTS_PER_WORKER);
        let errors = a.errors + b.errors;
        let best = if a.percentile(0.99) <= b.percentile(0.99) { a } else { b };
        (best, errors)
    }

    pub fn run() {
        let quick = std::env::var("ERA_BENCH_QUICK").is_ok();
        // fd budget: each held connection costs 2 fds in this process
        // (client stream + server conn); 240 stays far inside the
        // default 1024 soft limit with the load generator on top.
        let (legacy_conns, gateway_conns) = if quick { (25, 100) } else { (60, 240) };
        println!(
            "gateway scaling: {legacy_conns} held conns (blocking) vs {gateway_conns} (gateway), \
             load {WORKERS} workers x {REQUESTS_PER_WORKER} requests x {ROWS} rows x {NFE} NFE \
             (1ms/eval)"
        );

        // ---- Phase 1: blocking thread-per-connection baseline ----
        let legacy_pool = pool();
        let server = Server::start(
            legacy_pool.clone(),
            ServerConfig {
                max_connections: legacy_conns + WORKERS + 8,
                ..ServerConfig::default()
            },
        )
        .expect("bind blocking server");
        let idle = hold_idle(server.local_addr(), legacy_conns);
        let (legacy, legacy_errors) = best_of_two(server.local_addr());
        let legacy_p99 = legacy.percentile(0.99);
        println!(
            "BENCHLINE gateway/legacy conns={legacy_conns} p99={:.1}ms errors={legacy_errors}",
            1e3 * legacy_p99
        );
        drop(idle);
        server.shutdown();

        // ---- Phase 2: epoll gateway at 4x the held connections ----
        let gw_pool = pool();
        let gateway = Gateway::start(
            gw_pool.clone(),
            GatewayConfig {
                max_connections: gateway_conns + WORKERS + 8,
                ..GatewayConfig::default()
            },
        )
        .expect("bind gateway");
        let idle = hold_idle(gateway.local_addr(), gateway_conns);
        // Telemetry gate: the gauge must have seen the whole herd.
        let open = gw_pool.conn_snapshot().open_connections;
        let gauge_ok = open >= gateway_conns;
        println!(
            "BENCHLINE gateway/gauge open_connections={open} held={gateway_conns}: {}",
            if gauge_ok { "PASS" } else { "FAIL" }
        );
        let (gw, gw_errors) = best_of_two(gateway.local_addr());
        let gw_p99 = gw.percentile(0.99);
        println!(
            "BENCHLINE gateway/gateway conns={gateway_conns} p99={:.1}ms errors={gw_errors}",
            1e3 * gw_p99
        );
        drop(idle);
        gateway.shutdown();

        let conn_ratio = gateway_conns as f64 / legacy_conns as f64;
        let errors = legacy_errors + gw_errors;
        let p99_parity = if gw_p99 > 0.0 { legacy_p99 / gw_p99 } else { 1.0 };
        println!(
            "gateway held {conn_ratio:.1}x the connections at p99 parity {p99_parity:.2} \
             (legacy {:.1}ms vs gateway {:.1}ms) — targets: ratio >= 4, parity >= 0.5, \
             errors == 0: {}",
            1e3 * legacy_p99,
            1e3 * gw_p99,
            if conn_ratio >= 4.0 && p99_parity >= 0.5 && errors == 0 { "PASS" } else { "FAIL" }
        );
        assert!(conn_ratio >= 4.0, "held-connection ratio {conn_ratio:.1} below the 4x gate");
        assert!(gauge_ok, "open_connections gauge saw {open} of {gateway_conns} held conns");
        assert_eq!(errors, 0, "request errors under the connection herds");
        assert!(
            p99_parity >= 0.5,
            "gateway p99 {:.1}ms vs legacy {:.1}ms breaches the 2x parity gate at 4x conns",
            1e3 * gw_p99,
            1e3 * legacy_p99
        );

        // Committed gates are machine-independent (a ratio, a parity
        // bound checked against a 0.5 baseline, an error count, a
        // telemetry flag); absolute p99s ride along for trend tracking.
        let mut report = BenchReport::new("gateway");
        report.push("conn_ratio", conn_ratio, Direction::HigherIsBetter, 0.0);
        report.push("p99_parity", p99_parity.min(1.0), Direction::HigherIsBetter, 0.0);
        report.push("errors", errors as f64, Direction::LowerIsBetter, 0.0);
        report.push("gauge_ok", if gauge_ok { 1.0 } else { 0.0 }, Direction::HigherIsBetter, 0.0);
        report.push("legacy_p99_ms", 1e3 * legacy_p99, Direction::LowerIsBetter, 2.0);
        report.push("gateway_p99_ms", 1e3 * gw_p99, Direction::LowerIsBetter, 2.0);
        report.write_if_env();
    }
}
