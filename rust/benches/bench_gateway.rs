//! Connection-scaling bench: the epoll gateway vs the blocking
//! thread-per-connection server, same wire protocol, same pool, same
//! closed-loop load — but the gateway carries **4x the held-open
//! connection count** while it serves.
//!
//! Each phase opens a herd of idle keep-alive connections (pinged once
//! so the accept has completed), then runs the load generator twice and
//! keeps the better p99 (shared CI runners are noisy). CI gates
//! (ISSUE: readiness gateway):
//!
//! - `conn_ratio`  — gateway held connections / legacy held connections,
//!   4.0 by construction; regresses if the gateway cannot even hold them.
//! - `p99_parity`  — legacy p99 / gateway p99 at that 4x count; >= 0.5
//!   means the gateway's p99 is no worse than 2x the legacy server's
//!   while multiplexing 4x the connections on 2 io threads.
//! - `errors`      — total failed requests across both phases; must be 0.
//! - `gauge_ok`    — 1.0 when `open_connections` telemetry saw the
//!   whole gateway herd.
//!
//! A third phase measures **sample delivery** (ISSUE: zero-copy binary
//! frames): dim-512 `return_samples` requests over the gateway, once
//! with JSON row encoding and once with negotiated binary payloads.
//! Gates:
//!
//! - `payload_throughput_ratio` — binary rows/s over JSON rows/s;
//!   >= 2x, since the binary path skips the decimal round-trip on both
//!   sides and writes the result tensor zero-copy.
//! - `reply_allocs_per_request` — heap allocations on a warm session's
//!   reply path (completion -> encode -> drain) for one binary reply,
//!   counted by a global counting allocator; steady state is the
//!   pooled header buffer plus the payload's `Arc`, so ~1.
//!
//! Absolute p99s and per-encoding rows/s ride along uncommitted for
//! trend tracking.
//!
//! ```text
//! cargo bench --bench bench_gateway               # 60 vs 240 conns
//! ERA_BENCH_QUICK=1 cargo bench --bench bench_gateway   # 25 vs 100
//! ```

#[cfg(target_os = "linux")]
struct CountingAlloc;

#[cfg(target_os = "linux")]
static ALLOCS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

// Counts alloc+realloc so the reply-path measurement in phase 3 can
// assert the warm binary path stays allocation-free apart from the
// payload Arc. dealloc is uncounted (frees are not the gated cost).
#[cfg(target_os = "linux")]
unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::alloc::System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::alloc::System.realloc(ptr, layout, new_size)
    }
}

#[cfg(target_os = "linux")]
#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[cfg(not(target_os = "linux"))]
fn main() {
    println!("bench_gateway: skipped (the readiness gateway requires Linux epoll)");
}

#[cfg(target_os = "linux")]
fn main() {
    linux::run();
}

#[cfg(target_os = "linux")]
mod linux {
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::Arc;
    use std::time::Duration;

    use era_solver::coordinator::service::{MockBank, ModelBank};
    use era_solver::coordinator::{CoordinatorConfig, RequestSpec};
    use era_solver::obs::{BenchReport, Direction};
    use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
    use era_solver::server::client::{generate_load, generate_load_with, LoadOptions, LoadReport};
    use era_solver::server::gateway::{Gateway, GatewayConfig};
    use era_solver::server::protocol::Encoding;
    use era_solver::server::session::{ReadyFn, Session, SessionConfig};
    use era_solver::server::{Server, ServerConfig};
    use era_solver::solvers::eps_model::{AnalyticGmm, EpsModel};
    use era_solver::solvers::schedule::VpSchedule;
    use era_solver::tensor::Tensor;

    use super::ALLOCS;

    fn allocs() -> u64 {
        ALLOCS.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// MockBank wrapper with a fixed latency per evaluation — a stable
    /// per-request service-time floor (NFE x 1ms) so the p99s being
    /// compared are dominated by serving behaviour, not by noise around
    /// a microsecond-scale analytic eval.
    struct LatencyBank {
        inner: MockBank,
        per_eval: Duration,
    }

    impl ModelBank for LatencyBank {
        fn sched(&self) -> VpSchedule {
            self.inner.sched()
        }

        fn dim(&self, dataset: &str) -> Result<usize, String> {
            self.inner.dim(dataset)
        }

        fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
            std::thread::sleep(self.per_eval);
            self.inner.eval(dataset, x, t)
        }
    }

    const NFE: usize = 5;
    const ROWS: usize = 8;
    const WORKERS: usize = 4;
    const REQUESTS_PER_WORKER: usize = 5;
    /// Delivery-lane payload width (ISSUE: dim 512 with return_samples).
    const DELIVERY_DIM: usize = 512;

    /// Trivial wide model: eps = 0.1 * x at [`DELIVERY_DIM`]. A
    /// memcpy-scale evaluation keeps the delivery lane dominated by
    /// result serialization, not compute.
    struct WideEps;

    impl EpsModel for WideEps {
        fn eval(&self, x: &Tensor, _t: &[f32]) -> Tensor {
            let mut out = x.clone();
            out.scale(0.1);
            out
        }

        fn dim(&self) -> usize {
            DELIVERY_DIM
        }
    }

    fn pool() -> Arc<WorkerPool> {
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> = Arc::new(LatencyBank {
            inner: MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
            per_eval: Duration::from_millis(1),
        });
        Arc::new(WorkerPool::start(
            bank,
            PoolConfig {
                shards: 1,
                placement: PlacementPolicy::RoundRobin,
                shard: CoordinatorConfig::default(),
                max_inflight_rows: 0,
            },
        ))
    }

    fn spec() -> RequestSpec {
        RequestSpec { n_samples: ROWS, nfe: NFE, ..Default::default() }
    }

    /// Zero-latency pool serving the wide model (delivery lane).
    fn wide_pool() -> Arc<WorkerPool> {
        let sched = VpSchedule::default();
        let bank: Arc<dyn ModelBank> =
            Arc::new(MockBank::new(sched).with("wide512", Box::new(WideEps)));
        Arc::new(WorkerPool::start(
            bank,
            PoolConfig {
                shards: 1,
                placement: PlacementPolicy::RoundRobin,
                shard: CoordinatorConfig::default(),
                max_inflight_rows: 0,
            },
        ))
    }

    /// Allocations on a warm session's reply path for one binary
    /// `return_samples` reply: complete the request off-thread first,
    /// then count only `on_complete` (encode + enqueue) plus the drain.
    /// Minimum over the measured rounds rejects background-thread noise.
    fn measure_reply_allocs(rows: usize) -> f64 {
        use std::sync::mpsc;

        let pool = wide_pool();
        let (tx, rx) = mpsc::channel();
        let ready: ReadyFn = Arc::new(move |token| drop(tx.send(token)));
        let mut s = Session::new(pool.clone(), &SessionConfig::default(), ready);
        let req = format!(
            "{{\"op\":\"sample\",\"dataset\":\"wide512\",\"n_samples\":{rows},\"nfe\":{NFE},\
             \"seed\":7,\"return_samples\":true,\"encoding\":\"bin\"}}\n"
        );
        let mut best = u64::MAX;
        for round in 0..12 {
            s.on_bytes(req.as_bytes());
            let token = rx.recv_timeout(Duration::from_secs(30)).expect("delivery completion");
            // Let the shard finish its post-notify bookkeeping so the
            // counted window sees only this thread.
            std::thread::sleep(Duration::from_millis(2));
            let before = allocs();
            s.on_complete(token);
            while s.has_output() {
                let n = s.out_slice().len();
                s.consume_out(n);
            }
            let after = allocs();
            if round >= 4 {
                best = best.min(after - before);
            }
        }
        best as f64
    }

    /// Open `n` keep-alive connections, ping each once (so the accept
    /// and session installation have completed), and hold the raw
    /// streams open. One fd per connection on each side.
    fn hold_idle(addr: SocketAddr, n: usize) -> Vec<TcpStream> {
        let mut held = Vec::with_capacity(n);
        for i in 0..n {
            let mut s = TcpStream::connect(addr)
                .unwrap_or_else(|e| panic!("idle connect {i}/{n}: {e}"));
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut got = Vec::new();
            let mut buf = [0u8; 256];
            loop {
                let k = s.read(&mut buf).unwrap_or_else(|e| panic!("idle ping {i}: {e}"));
                assert!(k > 0, "server closed idle connection {i} of {n}");
                got.extend_from_slice(&buf[..k]);
                if got.contains(&b'\n') {
                    break;
                }
            }
            held.push(s);
        }
        held
    }

    /// Run the closed loop twice against `addr` and keep the run with
    /// the better p99 (errors are summed — a retry must not hide them).
    fn best_of_two(addr: SocketAddr) -> (LoadReport, usize) {
        let a = generate_load(addr, &spec(), WORKERS, REQUESTS_PER_WORKER);
        let b = generate_load(addr, &spec(), WORKERS, REQUESTS_PER_WORKER);
        let errors = a.errors + b.errors;
        let best = if a.percentile(0.99) <= b.percentile(0.99) { a } else { b };
        (best, errors)
    }

    pub fn run() {
        let quick = std::env::var("ERA_BENCH_QUICK").is_ok();
        // fd budget: each held connection costs 2 fds in this process
        // (client stream + server conn); 240 stays far inside the
        // default 1024 soft limit with the load generator on top.
        let (legacy_conns, gateway_conns) = if quick { (25, 100) } else { (60, 240) };
        println!(
            "gateway scaling: {legacy_conns} held conns (blocking) vs {gateway_conns} (gateway), \
             load {WORKERS} workers x {REQUESTS_PER_WORKER} requests x {ROWS} rows x {NFE} NFE \
             (1ms/eval)"
        );

        // ---- Phase 1: blocking thread-per-connection baseline ----
        let legacy_pool = pool();
        let server = Server::start(
            legacy_pool.clone(),
            ServerConfig {
                max_connections: legacy_conns + WORKERS + 8,
                ..ServerConfig::default()
            },
        )
        .expect("bind blocking server");
        let idle = hold_idle(server.local_addr(), legacy_conns);
        let (legacy, legacy_errors) = best_of_two(server.local_addr());
        let legacy_p99 = legacy.percentile(0.99);
        println!(
            "BENCHLINE gateway/legacy conns={legacy_conns} p99={:.1}ms errors={legacy_errors}",
            1e3 * legacy_p99
        );
        drop(idle);
        server.shutdown();

        // ---- Phase 2: epoll gateway at 4x the held connections ----
        let gw_pool = pool();
        let gateway = Gateway::start(
            gw_pool.clone(),
            GatewayConfig {
                max_connections: gateway_conns + WORKERS + 8,
                ..GatewayConfig::default()
            },
        )
        .expect("bind gateway");
        let idle = hold_idle(gateway.local_addr(), gateway_conns);
        // Telemetry gate: the gauge must have seen the whole herd.
        let open = gw_pool.conn_snapshot().open_connections;
        let gauge_ok = open >= gateway_conns;
        println!(
            "BENCHLINE gateway/gauge open_connections={open} held={gateway_conns}: {}",
            if gauge_ok { "PASS" } else { "FAIL" }
        );
        let (gw, gw_errors) = best_of_two(gateway.local_addr());
        let gw_p99 = gw.percentile(0.99);
        println!(
            "BENCHLINE gateway/gateway conns={gateway_conns} p99={:.1}ms errors={gw_errors}",
            1e3 * gw_p99
        );
        drop(idle);
        gateway.shutdown();

        let conn_ratio = gateway_conns as f64 / legacy_conns as f64;
        let errors = legacy_errors + gw_errors;
        let p99_parity = if gw_p99 > 0.0 { legacy_p99 / gw_p99 } else { 1.0 };
        println!(
            "gateway held {conn_ratio:.1}x the connections at p99 parity {p99_parity:.2} \
             (legacy {:.1}ms vs gateway {:.1}ms) — targets: ratio >= 4, parity >= 0.5, \
             errors == 0: {}",
            1e3 * legacy_p99,
            1e3 * gw_p99,
            if conn_ratio >= 4.0 && p99_parity >= 0.5 && errors == 0 { "PASS" } else { "FAIL" }
        );
        assert!(conn_ratio >= 4.0, "held-connection ratio {conn_ratio:.1} below the 4x gate");
        assert!(gauge_ok, "open_connections gauge saw {open} of {gateway_conns} held conns");
        assert_eq!(errors, 0, "request errors under the connection herds");
        assert!(
            p99_parity >= 0.5,
            "gateway p99 {:.1}ms vs legacy {:.1}ms breaches the 2x parity gate at 4x conns",
            1e3 * gw_p99,
            1e3 * legacy_p99
        );

        // ---- Phase 3: sample delivery, JSON rows vs binary payloads ----
        let (delivery_rows, delivery_reqs) = if quick { (32, 4) } else { (64, 8) };
        let delivery_pool = wide_pool();
        let delivery_gw =
            Gateway::start(delivery_pool.clone(), GatewayConfig::default()).expect("bind delivery");
        let dspec = RequestSpec {
            dataset: "wide512".into(),
            n_samples: delivery_rows,
            nfe: NFE,
            ..Default::default()
        };
        let leg = |encoding| {
            generate_load_with(
                delivery_gw.local_addr(),
                &dspec,
                &LoadOptions {
                    concurrency: 2,
                    requests_per_worker: delivery_reqs,
                    reuse: true,
                    encoding,
                },
            )
        };
        let _warm = leg(Encoding::Json); // warm pool buffers + lanes
        let json_leg = leg(Encoding::Json);
        let bin_leg = leg(Encoding::Bin);
        let delivery_errors = json_leg.errors + bin_leg.errors;
        let payload_ratio = bin_leg.throughput_rows / json_leg.throughput_rows.max(1e-9);
        delivery_gw.shutdown();
        let reply_allocs = measure_reply_allocs(delivery_rows);
        println!(
            "BENCHLINE gateway/delivery dim={DELIVERY_DIM} rows={delivery_rows} \
             json_rows_per_s={:.0} bin_rows_per_s={:.0} ratio={payload_ratio:.2} \
             reply_allocs={reply_allocs} errors={delivery_errors} — targets: \
             ratio >= 2, allocs <= 5, errors == 0: {}",
            json_leg.throughput_rows,
            bin_leg.throughput_rows,
            if payload_ratio >= 2.0 && reply_allocs <= 5.0 && delivery_errors == 0 {
                "PASS"
            } else {
                "FAIL"
            }
        );
        assert_eq!(delivery_errors, 0, "request errors in the delivery lane");
        assert!(
            payload_ratio >= 2.0,
            "binary delivery {:.0} rows/s is under 2x the JSON path's {:.0} rows/s",
            bin_leg.throughput_rows,
            json_leg.throughput_rows
        );
        assert!(
            reply_allocs <= 5.0,
            "warm binary reply path performed {reply_allocs} heap allocations"
        );

        // Committed gates are machine-independent (a ratio, a parity
        // bound checked against a 0.5 baseline, an error count, a
        // telemetry flag); absolute p99s ride along for trend tracking.
        let mut report = BenchReport::new("gateway");
        report.push("conn_ratio", conn_ratio, Direction::HigherIsBetter, 0.0);
        report.push("p99_parity", p99_parity.min(1.0), Direction::HigherIsBetter, 0.0);
        report.push("errors", errors as f64, Direction::LowerIsBetter, 0.0);
        report.push("gauge_ok", if gauge_ok { 1.0 } else { 0.0 }, Direction::HigherIsBetter, 0.0);
        report.push("payload_throughput_ratio", payload_ratio, Direction::HigherIsBetter, 0.0);
        report.push("reply_allocs_per_request", reply_allocs, Direction::LowerIsBetter, 1.0);
        report.push("legacy_p99_ms", 1e3 * legacy_p99, Direction::LowerIsBetter, 2.0);
        report.push("gateway_p99_ms", 1e3 * gw_p99, Direction::LowerIsBetter, 2.0);
        report.push("json_rows_per_s", json_leg.throughput_rows, Direction::HigherIsBetter, 2.0);
        report.push("bin_rows_per_s", bin_leg.throughput_rows, Direction::HigherIsBetter, 2.0);
        report.write_if_env();
    }
}
