//! Table benches: one representative cell per paper table, end to end
//! through the PJRT artifacts (the full generate + FID pipeline the
//! `examples/table_*` drivers sweep). Skips gracefully when artifacts
//! are missing. `ERA_BENCH_QUICK=1` shrinks iteration counts.

use std::sync::Arc;

use era_solver::benchkit::Bench;
use era_solver::experiments::sweep::{generate, EvalBackend};
use era_solver::metrics;
use era_solver::runtime::PjRtEngine;
use era_solver::solvers::schedule::GridKind;
use era_solver::solvers::SolverKind;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_tables: no artifacts (run `make artifacts`); skipping");
        return;
    }
    let engine = Arc::new(PjRtEngine::new("artifacts").expect("engine"));
    let mut b = Bench::new();
    let n = 1024; // per-cell sample count for benching (tables use 4096+)

    // (table, dataset, solver, nfe, grid, t_end)
    let cells = [
        ("tab1/church", "checkerboard", "era-4@0.3", 10, GridKind::Uniform, 1e-4),
        ("tab2/bedroom", "swissroll", "era-3@0.3", 10, GridKind::Uniform, 1e-4),
        ("tab3/cifar", "gmm8", "era-4@0.9", 10, GridKind::LogSnr, 1e-3),
        ("tab6/celeba", "rings", "era-4@0.3", 10, GridKind::Quadratic, 1e-4),
        ("tab4/ers-ablation", "checkerboard", "era-fixed-5", 10, GridKind::Uniform, 1e-4),
        ("fig5/scale-ablation", "checkerboard", "era-const-3@1", 10, GridKind::Uniform, 1e-4),
        ("baseline/ddim", "checkerboard", "ddim", 10, GridKind::Uniform, 1e-4),
        ("baseline/dpm-fast", "checkerboard", "dpm-fast", 10, GridKind::Uniform, 1e-4),
        ("highdim/patches64", "patches64", "era-4@0.3", 10, GridKind::Uniform, 1e-4),
    ];
    for (label, dataset, solver, nfe, grid, t_end) in cells {
        let backend = EvalBackend::pjrt(engine.clone(), dataset).expect(dataset);
        let reference = backend.reference();
        let kind = SolverKind::parse(solver).unwrap();
        b.case(&format!("{label} {solver}@{nfe} n={n}"), || {
            let (samples, _) = generate(&backend, &kind, nfe, grid, t_end, n, 256, 0);
            metrics::fid(&samples, &reference)
        });
    }
    eprintln!(
        "\nPJRT totals: {} executions, {} rows, {} compiles",
        engine.eval_count(),
        engine.rows_executed(),
        engine.compile_count()
    );
}
