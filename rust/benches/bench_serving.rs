//! Serving benches (Tab. 7's substrate): coordinator round-trip latency
//! and fused-batch throughput, on both the in-process mock bank (isolates
//! coordinator overhead) and the PJRT artifacts (end-to-end).

use std::sync::Arc;
use std::time::Duration;

use era_solver::benchkit::Bench;
use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, RequestSpec};
use era_solver::runtime::PjRtEngine;
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;

fn spec(n: usize, nfe: usize) -> RequestSpec {
    RequestSpec { n_samples: n, nfe, ..Default::default() }
}

fn main() {
    let mut b = Bench::new();

    // --- Coordinator overhead with an in-process model ---
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> =
        Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
    let coord = Coordinator::start(bank, CoordinatorConfig::default());
    b.case("coord/mock single 64x10nfe round-trip", || {
        coord.sample(spec(64, 10)).unwrap()
    });
    b.case("coord/mock 8 concurrent 64x10nfe", || {
        let tickets: Vec<_> = (0..8).map(|_| coord.submit(spec(64, 10)).unwrap()).collect();
        tickets.into_iter().map(|t| t.wait().unwrap().nfe).sum::<usize>()
    });
    drop(coord);

    // --- End-to-end over PJRT artifacts ---
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_serving: no artifacts; PJRT section skipped");
        return;
    }
    let engine = Arc::new(PjRtEngine::new("artifacts").expect("engine"));
    engine.warmup("gmm8", &engine.manifest().batch_buckets.clone()).unwrap();
    let coord = Coordinator::start(engine.clone(), CoordinatorConfig::default());

    for (label, n, nfe) in [
        ("pjrt single 16x10nfe", 16, 10),
        ("pjrt single 256x10nfe", 256, 10),
        ("pjrt single 256x50nfe", 256, 50),
    ] {
        b.case(&format!("coord/{label}"), || coord.sample(spec(n, nfe)).unwrap());
    }
    b.case("coord/pjrt 8 concurrent 64x10nfe (fused)", || {
        let tickets: Vec<_> = (0..8).map(|_| coord.submit(spec(64, 10)).unwrap()).collect();
        tickets.into_iter().map(|t| t.wait().unwrap().nfe).sum::<usize>()
    });
    println!("telemetry: {}", coord.telemetry().summary());
    drop(coord);

    // --- Linger policy impact (batch formation under trickle load) ---
    for (label, policy) in [
        (
            "no-linger",
            BatchPolicy { max_rows: 256, min_rows: 1, max_wait: Duration::from_millis(0) },
        ),
        (
            "linger-2ms",
            BatchPolicy { max_rows: 256, min_rows: 64, max_wait: Duration::from_millis(2) },
        ),
    ] {
        let coord = Coordinator::start(
            engine.clone(),
            CoordinatorConfig { max_active: 32, queue_capacity: 128, policy, ..Default::default() },
        );
        b.case(&format!("coord/pjrt policy {label} 8x(32 rows)"), || {
            let tickets: Vec<_> =
                (0..8).map(|_| coord.submit(spec(32, 10)).unwrap()).collect();
            tickets.into_iter().map(|t| t.wait().unwrap().nfe).sum::<usize>()
        });
        println!("  {label}: {}", coord.telemetry().summary());
        drop(coord);
    }
}
