//! Pool scaling bench: sampling throughput at 1/2/4 coordinator shards
//! over a `MockBank` whose evaluation cost is proportional to the rows
//! it executes (emulating a device-bound denoiser, where a slab's cost
//! scales with its batch). With one shard every round's row mass runs
//! through one loop thread; with N shards the same mass runs N-wide, so
//! throughput should scale until cores (or the row mass) run out.
//!
//! Acceptance target (ISSUE 1): >= 2x throughput at 4 shards vs 1.
//!
//! ```text
//! cargo bench --bench bench_pool
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{CoordinatorConfig, RequestSpec};
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::solvers::eps_model::AnalyticGmm;
use era_solver::solvers::schedule::VpSchedule;
use era_solver::tensor::Tensor;

/// MockBank wrapper whose eval latency is `per_row * rows` — the cost
/// model of a throughput-bound accelerator (sleeping, not spinning, so
/// N shards overlap even on few cores).
struct RowCostBank {
    inner: MockBank,
    per_row: Duration,
}

impl RowCostBank {
    fn gmm8(per_row: Duration) -> RowCostBank {
        let sched = VpSchedule::default();
        RowCostBank {
            inner: MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
            per_row,
        }
    }
}

impl ModelBank for RowCostBank {
    fn sched(&self) -> VpSchedule {
        self.inner.sched()
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        self.inner.dim(dataset)
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        std::thread::sleep(self.per_row * x.rows() as u32);
        self.inner.eval(dataset, x, t)
    }
}

const REQUESTS: usize = 16;
const ROWS: usize = 64;
const NFE: usize = 10;

/// Drive the fixed workload through a pool with `shards` shards and
/// return samples/second.
fn run_once(shards: usize) -> f64 {
    let bank: Arc<dyn ModelBank> = Arc::new(RowCostBank::gmm8(Duration::from_micros(20)));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 0,
        },
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..REQUESTS)
        .map(|i| {
            pool.submit(RequestSpec {
                n_samples: ROWS,
                nfe: NFE,
                seed: i as u64,
                ..Default::default()
            })
            .expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("sample");
    }
    let wall = t0.elapsed().as_secs_f64();
    pool.shutdown();
    (REQUESTS * ROWS) as f64 / wall
}

fn median_throughput(shards: usize, reps: usize) -> f64 {
    let mut runs: Vec<f64> = (0..reps).map(|_| run_once(shards)).collect();
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    runs[runs.len() / 2]
}

fn main() {
    println!(
        "pool scaling: {REQUESTS} requests x {ROWS} rows x {NFE} NFE, \
         row-proportional eval cost (20us/row)"
    );
    let mut base = 0.0;
    let mut at4 = 0.0;
    for shards in [1usize, 2, 4] {
        let thpt = median_throughput(shards, 3);
        if shards == 1 {
            base = thpt;
        }
        if shards == 4 {
            at4 = thpt;
        }
        let speedup = if base > 0.0 { thpt / base } else { 1.0 };
        println!(
            "BENCHLINE pool/shards={shards} throughput={thpt:.0} samples/s speedup={speedup:.2}x"
        );
    }
    let target = 2.0;
    let speedup = if base > 0.0 { at4 / base } else { 0.0 };
    println!(
        "pool 4-shard speedup {speedup:.2}x vs 1 shard — target >= {target:.1}x: {}",
        if speedup >= target { "PASS" } else { "FAIL" }
    );
}
