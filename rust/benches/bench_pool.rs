//! Pool scaling bench, two sweeps:
//!
//! 1. **Shard sweep** — sampling throughput at 1/2/4 coordinator shards
//!    over a `MockBank` whose evaluation cost is proportional to the
//!    rows it executes (emulating a device-bound denoiser). Acceptance
//!    target (ISSUE 1): >= 2x throughput at 4 shards vs 1.
//! 2. **Pipeline sweep** — one shard, `executors x pipeline_depth`
//!    over a fixed-latency MockBank with one-request slabs, measuring
//!    how much of the engine latency the pipelined scheduler hides.
//!    CI gate (ISSUE 4): 2 executors at depth 2 must reach >= 1.3x the
//!    serialized 1-executor depth-1 baseline.
//! 3. **Adaptive-NFE gate** — a converging (constant-eps) workload
//!    under the balanced QoS class with the convergence controller on
//!    must deliver a mean NFE >= 20% below the fixed-budget baseline
//!    (`adaptive_nfe_reduction` in BENCH_pool.json).
//!
//! ```text
//! cargo bench --bench bench_pool               # full sweeps
//! ERA_BENCH_QUICK=1 cargo bench --bench bench_pool   # CI gate only
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, QosClass, RequestSpec};
use era_solver::obs::{BenchReport, Direction};
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::solvers::eps_model::{AnalyticGmm, EpsModel};
use era_solver::solvers::schedule::VpSchedule;
use era_solver::tensor::Tensor;

/// MockBank wrapper whose eval latency is `per_row * rows` — the cost
/// model of a throughput-bound accelerator (sleeping, not spinning, so
/// N shards overlap even on few cores).
struct RowCostBank {
    inner: MockBank,
    per_row: Duration,
}

impl RowCostBank {
    fn gmm8(per_row: Duration) -> RowCostBank {
        let sched = VpSchedule::default();
        RowCostBank {
            inner: MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
            per_row,
        }
    }
}

impl ModelBank for RowCostBank {
    fn sched(&self) -> VpSchedule {
        self.inner.sched()
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        self.inner.dim(dataset)
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        std::thread::sleep(self.per_row * x.rows() as u32);
        self.inner.eval(dataset, x, t)
    }
}

const REQUESTS: usize = 16;
const ROWS: usize = 64;
const NFE: usize = 10;

/// Drive the fixed workload through a pool with `shards` shards and
/// return samples/second.
fn run_once(shards: usize) -> f64 {
    let bank: Arc<dyn ModelBank> = Arc::new(RowCostBank::gmm8(Duration::from_micros(20)));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 0,
        },
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..REQUESTS)
        .map(|i| {
            pool.submit(RequestSpec {
                n_samples: ROWS,
                nfe: NFE,
                seed: i as u64,
                ..Default::default()
            })
            .expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("sample");
    }
    let wall = t0.elapsed().as_secs_f64();
    pool.shutdown();
    (REQUESTS * ROWS) as f64 / wall
}

fn median_throughput(shards: usize, reps: usize) -> f64 {
    let mut runs: Vec<f64> = (0..reps).map(|_| run_once(shards)).collect();
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    runs[runs.len() / 2]
}

/// MockBank wrapper with a fixed latency per evaluation regardless of
/// rows — the cost model of a small-batch accelerator call, where the
/// win comes from keeping several calls in flight, not from bigger
/// slabs. Sleeping, not spinning, so executors overlap on few cores.
struct LatencyBank {
    inner: MockBank,
    per_eval: Duration,
}

impl LatencyBank {
    fn gmm8(per_eval: Duration) -> LatencyBank {
        let sched = VpSchedule::default();
        LatencyBank {
            inner: MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))),
            per_eval,
        }
    }
}

impl ModelBank for LatencyBank {
    fn sched(&self) -> VpSchedule {
        self.inner.sched()
    }

    fn dim(&self, dataset: &str) -> Result<usize, String> {
        self.inner.dim(dataset)
    }

    fn eval(&self, dataset: &str, x: &Tensor, t: &[f32]) -> Result<Tensor, String> {
        std::thread::sleep(self.per_eval);
        self.inner.eval(dataset, x, t)
    }
}

const PIPE_REQUESTS: usize = 8;
const PIPE_ROWS: usize = 16;
const PIPE_NFE: usize = 10;
const PIPE_EVAL_MS: u64 = 2;

/// One shard, `executors` engine executors, `depth` rounds in flight.
/// `max_rows = PIPE_ROWS` keeps every request its own slab, so the
/// sweep isolates pipelining from batching.
fn run_pipeline_once(executors: usize, depth: usize) -> f64 {
    let bank: Arc<dyn ModelBank> =
        Arc::new(LatencyBank::gmm8(Duration::from_millis(PIPE_EVAL_MS)));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards: 1,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig {
                policy: BatchPolicy {
                    max_rows: PIPE_ROWS,
                    min_rows: 1,
                    max_wait: Duration::from_millis(0),
                },
                executors_per_shard: executors,
                pipeline_depth: depth,
                ..Default::default()
            },
            max_inflight_rows: 0,
        },
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..PIPE_REQUESTS)
        .map(|i| {
            pool.submit(RequestSpec {
                n_samples: PIPE_ROWS,
                nfe: PIPE_NFE,
                seed: i as u64,
                ..Default::default()
            })
            .expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("sample");
    }
    let wall = t0.elapsed().as_secs_f64();
    pool.shutdown();
    (PIPE_REQUESTS * PIPE_ROWS) as f64 / wall
}

fn median_pipeline_throughput(executors: usize, depth: usize, reps: usize) -> f64 {
    let mut runs: Vec<f64> = (0..reps).map(|_| run_pipeline_once(executors, depth)).collect();
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    runs[runs.len() / 2]
}

/// Constant-eps model: Lagrange prediction of a constant is exact, so
/// `delta_eps` collapses after the ERA warmup and the convergence
/// controller fires as early as its floor allows — the best case the
/// adaptive-NFE gate measures against the fixed-budget baseline.
struct ConstEps;

impl EpsModel for ConstEps {
    fn eval(&self, x: &Tensor, _t: &[f32]) -> Tensor {
        Tensor::from_vec(vec![0.25; x.rows() * x.cols()], x.rows(), x.cols())
    }

    fn dim(&self) -> usize {
        2
    }
}

const ADAPT_REQUESTS: usize = 8;
const ADAPT_ROWS: usize = 16;
const ADAPT_NFE: usize = 24;

/// Drive the converging workload through a one-shard pool and return
/// the mean delivered NFE. `conv_threshold` 0 is the fixed baseline.
fn mean_delivered_nfe(conv_threshold: f64) -> f64 {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> =
        Arc::new(MockBank::new(sched).with("const", Box::new(ConstEps)));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards: 1,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 0,
        },
    );
    let tickets: Vec<_> = (0..ADAPT_REQUESTS)
        .map(|i| {
            pool.submit(RequestSpec {
                dataset: "const".into(),
                n_samples: ADAPT_ROWS,
                nfe: ADAPT_NFE,
                seed: i as u64,
                qos: QosClass::Balanced,
                conv_threshold,
                ..Default::default()
            })
            .expect("submit")
        })
        .collect();
    let mut total_nfe = 0usize;
    for t in tickets {
        let res = t.wait().expect("sample");
        total_nfe += res.nfe;
    }
    pool.shutdown();
    total_nfe as f64 / ADAPT_REQUESTS as f64
}

fn main() {
    let quick = std::env::var("ERA_BENCH_QUICK").is_ok();
    let reps = if quick { 3 } else { 5 };

    if !quick {
        println!(
            "pool scaling: {REQUESTS} requests x {ROWS} rows x {NFE} NFE, \
             row-proportional eval cost (20us/row)"
        );
        let mut base = 0.0;
        let mut at4 = 0.0;
        for shards in [1usize, 2, 4] {
            let thpt = median_throughput(shards, 3);
            if shards == 1 {
                base = thpt;
            }
            if shards == 4 {
                at4 = thpt;
            }
            let speedup = if base > 0.0 { thpt / base } else { 1.0 };
            println!(
                "BENCHLINE pool/shards={shards} throughput={thpt:.0} samples/s \
                 speedup={speedup:.2}x"
            );
        }
        let target = 2.0;
        let speedup = if base > 0.0 { at4 / base } else { 0.0 };
        println!(
            "pool 4-shard speedup {speedup:.2}x vs 1 shard — target >= {target:.1}x: {}",
            if speedup >= target { "PASS" } else { "FAIL" }
        );
    }

    println!(
        "pipeline sweep: 1 shard, {PIPE_REQUESTS} requests x {PIPE_ROWS} rows x {PIPE_NFE} NFE, \
         fixed {PIPE_EVAL_MS}ms/eval, one-request slabs"
    );
    let mut serialized = 0.0;
    let mut gated = 0.0;
    let sweep: &[(usize, usize)] =
        if quick { &[(1, 1), (2, 2)] } else { &[(1, 1), (1, 2), (2, 1), (2, 2), (4, 4)] };
    for &(executors, depth) in sweep {
        let thpt = median_pipeline_throughput(executors, depth, reps);
        if (executors, depth) == (1, 1) {
            serialized = thpt;
        }
        if (executors, depth) == (2, 2) {
            gated = thpt;
        }
        let speedup = if serialized > 0.0 { thpt / serialized } else { 1.0 };
        println!(
            "BENCHLINE pool/executors={executors}_depth={depth} throughput={thpt:.0} \
             samples/s speedup={speedup:.2}x"
        );
    }
    // Acceptance (ISSUE 4): the pipelined scheduler must hide enough
    // engine latency for 2 executors at depth 2 to clearly beat the
    // serialized baseline. The theoretical ceiling here is ~2x; 1.3x
    // leaves room for scheduler jitter on shared CI runners.
    let speedup = if serialized > 0.0 { gated / serialized } else { 0.0 };
    println!(
        "pipeline 2x2 speedup {speedup:.2}x vs serialized — target >= 1.3x: {}",
        if speedup >= 1.3 { "PASS" } else { "FAIL" }
    );
    assert!(
        speedup >= 1.3,
        "pipelined 2-executor/depth-2 throughput {speedup:.2}x fell below the 1.3x gate"
    );

    // Perf-trajectory artifact (BENCH_pool.json when $ERA_BENCH_JSON_DIR
    // is set). The 2x2 speedup is a machine-independent ratio and gates
    // CI against the committed baseline; absolute throughputs ride along
    // for trend tracking only.
    // Adaptive-NFE sweep (runs in quick mode too — it is a CI gate):
    // a converging workload under the balanced class must deliver a
    // clearly smaller mean NFE than the same workload fixed-budget.
    let fixed_nfe = mean_delivered_nfe(0.0);
    let adaptive_nfe = mean_delivered_nfe(0.2);
    let reduction = if fixed_nfe > 0.0 { 1.0 - adaptive_nfe / fixed_nfe } else { 0.0 };
    println!(
        "BENCHLINE pool/adaptive mean_nfe fixed={fixed_nfe:.1} adaptive={adaptive_nfe:.1} \
         reduction={reduction:.2}"
    );
    println!(
        "adaptive NFE reduction {reduction:.2} on converging workload — target >= 0.2: {}",
        if reduction >= 0.2 { "PASS" } else { "FAIL" }
    );
    assert!(
        (fixed_nfe - ADAPT_NFE as f64).abs() < 1e-9,
        "threshold-0 baseline must run the full fixed budget, got {fixed_nfe}"
    );
    assert!(
        reduction >= 0.2,
        "adaptive mean NFE {adaptive_nfe:.1} vs fixed {fixed_nfe:.1} fell below the 20% gate"
    );

    let mut report = BenchReport::new("pool");
    report.push("pipeline_2x2_speedup", speedup, Direction::HigherIsBetter, 0.0);
    report.push("adaptive_nfe_reduction", reduction, Direction::HigherIsBetter, 0.0);
    report.push(
        "pipeline_serialized_samples_per_s",
        serialized,
        Direction::HigherIsBetter,
        0.8,
    );
    report.push("pipeline_2x2_samples_per_s", gated, Direction::HigherIsBetter, 0.8);
    report.write_if_env();
}
