//! Micro benchmarks over the L3 hot-path primitives: the solver-update
//! kernels, Lagrange machinery, ERS selection, batch packing, metric
//! evaluation and JSON framing. These are the §Perf iteration targets —
//! run with `cargo bench --offline` and diff the BENCHLINEs.

use era_solver::benchkit::{black_box, Bench};
use era_solver::coordinator::batcher::{Batcher, BatchPolicy};
use era_solver::json;
use era_solver::metrics::{self, Moments};
use era_solver::rng::Rng;
use era_solver::solvers::era::select_indices;
use era_solver::solvers::eps_model::{AnalyticGmm, EpsModel};
use era_solver::solvers::lagrange;
use era_solver::solvers::schedule::{make_grid, GridKind, VpSchedule};
use era_solver::solvers::{sample_with, EvalRequest, SolverKind};
use era_solver::tensor::Tensor;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0);

    // --- Tensor kernels (the per-step solver update) ---
    let x = rng.normal_tensor(256, 64);
    let eps: Vec<Tensor> = (0..4).map(|_| rng.normal_tensor(256, 64)).collect();
    let refs: Vec<&Tensor> = eps.iter().collect();
    let w = [0.4, 0.3, 0.2, 0.1];
    b.case("tensor/weighted_sum k=4 256x64", || {
        Tensor::weighted_sum(black_box(&refs), black_box(&w))
    });
    b.case("tensor/kernel_weighted_sum k=4 256x64", || {
        Tensor::kernel_weighted_sum(black_box(&x), 0.97, -0.1, black_box(&refs), &w)
    });
    let parts: Vec<&[f32]> = eps.iter().map(|e| e.as_slice()).collect();
    let mut fused_out = vec![0.0f32; x.len()];
    b.case("kernels/fused_affine_sum_into k=4 256x64", || {
        era_solver::kernels::fused::fused_affine_sum_into(
            black_box(&mut fused_out),
            0.97,
            x.as_slice(),
            -0.1,
            black_box(&parts),
            &w,
        );
        fused_out[0]
    });
    let mut xm = x.clone();
    b.case("tensor/affine_inplace 256x64", || {
        xm.affine_inplace(0.99, 0.01, black_box(&eps[0]));
        xm.as_slice()[0]
    });

    // --- Lagrange predictor + ERS selection ---
    let nodes = [0.9, 0.65, 0.4, 0.15];
    b.case("lagrange/weights k=4", || lagrange::weights(black_box(&nodes), 0.05));
    let vals: Vec<&Tensor> = eps.iter().collect();
    b.case("lagrange/interpolate k=4 256x64", || {
        lagrange::interpolate(black_box(&nodes), black_box(&vals), 0.05)
    });
    b.case("era/select_indices i=100 k=6", || select_indices(100, 6, black_box(2.7)));

    // --- Full solver step loop (in-process model, no PJRT) ---
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    b.case("solver/era-4 nfe=10 batch=256 (analytic eps)", || {
        let grid = make_grid(&sched, GridKind::Uniform, 10, 1.0, 1e-3);
        let mut lrng = Rng::new(1);
        let kind = SolverKind::parse("era").unwrap();
        let mut s = kind.build(sched, grid, lrng.normal_tensor(256, 2), 1, 10);
        sample_with(&mut *s, &model)
    });
    b.case("solver/ddim nfe=10 batch=256 (analytic eps)", || {
        let grid = make_grid(&sched, GridKind::Uniform, 10, 1.0, 1e-3);
        let mut lrng = Rng::new(1);
        let kind = SolverKind::parse("ddim").unwrap();
        let mut s = kind.build(sched, grid, lrng.normal_tensor(256, 2), 1, 10);
        sample_with(&mut *s, &model)
    });

    // --- Coordinator packing ---
    let reqs: Vec<EvalRequest> = (0..16)
        .map(|i| EvalRequest {
            x: std::sync::Arc::new(rng.normal_tensor(16 + i, 8)),
            t: 0.5,
            cond: None,
        })
        .collect();
    let pending: Vec<(usize, &EvalRequest)> = reqs.iter().enumerate().collect();
    let batcher = Batcher::new(BatchPolicy::default());
    b.case("batcher/pack 16 reqs ~370 rows", || batcher.pack(black_box(&pending)));

    // --- Metrics (per-table cost driver) ---
    let samples = rng.normal_tensor(4096, 2);
    let reference = Moments::new(vec![0.0, 0.0], vec![1.0, 0.0, 0.0, 1.0]);
    b.case("metrics/fid 4096x2", || metrics::fid(black_box(&samples), &reference));
    let hi = rng.normal_tensor(2048, 64);
    let ref_hi = Moments::from_tensor(&rng.normal_tensor(2048, 64));
    b.case("metrics/fid 2048x64 (sqrtm-bound)", || metrics::fid(black_box(&hi), &ref_hi));

    // --- Wire framing ---
    let payload = {
        let rows: Vec<json::Json> =
            (0..256).map(|r| json::Json::arr_f32(samples.row(r))).collect();
        json::Json::obj(vec![("samples", json::Json::Arr(rows))]).to_string()
    };
    b.case("json/parse 256x2 sample payload", || json::parse(black_box(&payload)).unwrap());

    // --- Analytic model eval (test-path baseline) ---
    let xt = rng.normal_tensor(256, 2);
    let ts = vec![0.5f32; 256];
    b.case("model/analytic_gmm eval 256x2", || model.eval(black_box(&xt), &ts));

    eprintln!("\n{} cases done", b.results().len());
}
