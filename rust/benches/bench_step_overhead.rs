//! Per-step *host* overhead of the solver layer: nanoseconds and heap
//! allocations spent inside `next_eval` + `on_eval`, with the model
//! evaluation excluded (its output tensor is produced outside the
//! counted/timed windows and moved in).
//!
//! A counting global allocator makes the acceptance criteria
//! checkable: after warmup (`k + 4` steps), an ERA step must perform
//! **zero** heap allocations — the plan owns all coefficients, the
//! scratch buffers are preallocated, and `EvalRequest` is a refcount
//! bump. A "simulated pre-refactor step" case re-enacts the old
//! allocating path (iterate clone per request, allocating weighted
//! sums and transfers, per-step Lagrange weights) on identical shapes
//! for the >= 1.5x comparison. A lanes-vs-boxed case steps a
//! 64-request shard both as one struct-of-arrays lane and as 64 boxed
//! `dyn Solver`s: the lane path must be allocation-free in steady
//! state and >= 1.5x lower host overhead per request-step (asserted
//! in quick mode too).
//!
//! ```text
//! cargo bench --bench bench_step_overhead            # full
//! ERA_BENCH_QUICK=1 cargo bench --bench bench_step_overhead
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use era_solver::benchkit::black_box;
use era_solver::coordinator::service::{MockBank, ModelBank};
use era_solver::coordinator::{Coordinator, CoordinatorConfig, RequestSpec};
use era_solver::obs::trace::pack_bases;
use era_solver::obs::{BenchReport, Direction, FlightRecorder, SpanKind};
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::rng::Rng;
use era_solver::solvers::adams_implicit::am_weights;
use era_solver::solvers::era::select_indices;
use era_solver::solvers::eps_model::{AnalyticGmm, EpsModel};
use era_solver::solvers::lagrange;
use era_solver::solvers::lanes::{LaneAdmission, LaneEngine};
use era_solver::solvers::schedule::{make_grid, GridKind, VpSchedule};
use era_solver::solvers::{Solver, SolverKind, TaskSpec};
use era_solver::tensor::Tensor;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

struct StepCost {
    label: String,
    steps: usize,
    ns_per_step: f64,
    allocs_per_step: f64,
    /// Max allocations observed in any single post-warmup step.
    steady_max_allocs: u64,
}

impl StepCost {
    fn line(&self) -> String {
        format!(
            "BENCHLINE step_overhead/{} steps={} ns_per_step={:.1} \
             allocs_per_step={:.3} steady_max_allocs={}",
            self.label, self.steps, self.ns_per_step, self.allocs_per_step, self.steady_max_allocs
        )
    }
}

/// Drive one trajectory measuring only the solver's own work: the
/// model runs between the counted windows and its output is moved in.
///
/// All trials replay one request shape over ONE shared plan (the
/// serving steady state); trial 0 warms the plan's Lagrange memo and is
/// excluded from the statistics, mirroring "after warmup" in the
/// acceptance criterion.
fn measure_solver(name: &str, rows: usize, nfe: usize, trials: usize) -> StepCost {
    measure_task_solver(name, rows, nfe, trials, &TaskSpec::default())
}

/// Like [`measure_solver`] but building the full workload stack for
/// `task` (guided wrapping, churn) — the guided case pins the paired-row
/// combine path at zero steady-state allocations.
fn measure_task_solver(
    name: &str,
    rows: usize,
    nfe: usize,
    trials: usize,
    task: &TaskSpec,
) -> StepCost {
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kind = SolverKind::parse(name).unwrap();
    let steps = kind.steps_for_nfe(nfe);
    let warmup_steps = match &kind {
        SolverKind::Era { k, .. } => k + 4,
        // PRK warmup costs 12 evaluations before the multistep phase.
        SolverKind::Pndm | SolverKind::Fon => 14,
        _ => 6,
    };
    let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
    let plan = Arc::new(kind.make_plan(sched, grid, nfe));

    let mut total_ns = 0u128;
    let mut total_steps = 0usize;
    let mut steady_allocs_sum = 0u64;
    let mut steady_steps = 0usize;
    let mut steady_max = 0u64;
    for trial in 0..=trials {
        let warm_trial = trial == 0;
        let mut rng = Rng::new(7);
        let mut s = kind
            .build_task(plan.clone(), rng.normal_tensor(rows, 2), 7, task)
            .expect("build workload solver");
        let mut t_buf: Vec<f32> = Vec::with_capacity(2 * rows);
        let mut c_buf: Vec<f32> = Vec::with_capacity(2 * rows);
        let mut step = 0usize;
        loop {
            let a0 = allocs();
            let t0 = Instant::now();
            let req = match s.next_eval() {
                Some(r) => r,
                None => break,
            };
            let ns_next = t0.elapsed().as_nanos();
            let a1 = allocs();

            // Model evaluation: outside both windows (the coordinator
            // side owns the t/c buffers, not the solver).
            t_buf.clear();
            t_buf.resize(req.x.rows(), req.t as f32);
            let eps = match &req.cond {
                None => model.eval(&req.x, &t_buf),
                Some(c) => {
                    c_buf.clear();
                    c_buf.extend_from_slice(c);
                    model.eval_cond(&req.x, &t_buf, &c_buf)
                }
            };
            drop(req);

            let a2 = allocs();
            let t1 = Instant::now();
            s.on_eval(eps);
            let ns_on = t1.elapsed().as_nanos();
            let a3 = allocs();

            // Both the timing and the allocation statistics cover only
            // post-warmup steps — the regime the acceptance criterion
            // describes.
            if !warm_trial && step >= warmup_steps {
                let step_allocs = (a1 - a0) + (a3 - a2);
                total_ns += ns_next + ns_on;
                total_steps += 1;
                steady_allocs_sum += step_allocs;
                steady_steps += 1;
                steady_max = steady_max.max(step_allocs);
            }
            step += 1;
        }
        black_box(s.current().as_slice()[0]);
    }
    let label = if *task == TaskSpec::default() {
        format!("{name} rows={rows}")
    } else {
        format!("{name}[{}] rows={rows}", task.label())
    };
    StepCost {
        label,
        steps: total_steps,
        ns_per_step: total_ns as f64 / total_steps.max(1) as f64,
        allocs_per_step: steady_allocs_sum as f64 / steady_steps.max(1) as f64,
        steady_max_allocs: steady_max,
    }
}

/// Re-enactment of the pre-refactor ERA step's host work on identical
/// shapes: clone the iterate for the EvalRequest, compute Lagrange
/// weights per step, allocate both weighted-sum combinations and the
/// transfer output. Same arithmetic volume, allocating data flow.
fn measure_naive_era(rows: usize, k: usize, nfe: usize, trials: usize) -> StepCost {
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let grid = make_grid(&sched, GridKind::Uniform, nfe, 1.0, 1e-3);
    let mut total_ns = 0u128;
    let mut total_steps = 0usize;
    let mut allocs_sum = 0u64;
    let mut steady_max = 0u64;
    for trial in 0..trials {
        let mut rng = Rng::new(trial as u64);
        let mut x = rng.normal_tensor(rows, 2);
        let mut buf: Vec<Tensor> = Vec::new();
        let mut t_vec: Vec<f64> = Vec::new();
        let mut delta = 5.0f64;
        for i in 0..grid.len() - 1 {
            // Model output produced outside the counted window, like the
            // production measurement above.
            let eps = model.eval(&x, &vec![grid[i] as f32; rows]);
            let a0 = allocs();
            let t0 = Instant::now();
            // Old next_eval: owned-x EvalRequest.
            let req_x = x.clone();
            buf.push(eps);
            t_vec.push(grid[i]);
            if buf.len() >= k {
                let bi = buf.len() - 1;
                let idx = select_indices(bi, k, delta / 5.0);
                let nodes: Vec<f64> = idx.iter().map(|&n| t_vec[n]).collect();
                let vals: Vec<&Tensor> = idx.iter().map(|&n| &buf[n]).collect();
                let pred = lagrange::interpolate(&nodes, &vals, grid[i + 1]);
                let order = buf.len().min(3) + 1;
                let w = am_weights(order);
                let mut tensors: Vec<&Tensor> = vec![&pred];
                for back in 0..order - 1 {
                    tensors.push(&buf[buf.len() - 1 - back]);
                }
                let eps_c = Tensor::weighted_sum(&tensors, w);
                let (a, b) = sched.ddim_coeffs(grid[i], grid[i + 1]);
                x = x.affine(a as f32, b as f32, &eps_c);
                delta = pred.mean_row_dist(buf.last().unwrap()) as f64;
            } else {
                let (a, b) = sched.ddim_coeffs(grid[i], grid[i + 1]);
                x = x.affine(a as f32, b as f32, buf.last().unwrap());
            }
            let ns = t0.elapsed().as_nanos();
            let spent = allocs() - a0;
            // Same post-warmup window as measure_solver so the speedup
            // ratio compares steady-state step against steady-state step.
            if i >= k + 4 {
                total_ns += ns;
                allocs_sum += spent;
                steady_max = steady_max.max(spent);
                total_steps += 1;
            }
            black_box(req_x.as_slice()[0]);
        }
    }
    StepCost {
        label: format!("naive-era-{k} rows={rows} (simulated pre-refactor)"),
        steps: total_steps,
        ns_per_step: total_ns as f64 / total_steps.max(1) as f64,
        allocs_per_step: allocs_sum as f64 / total_steps.max(1) as f64,
        steady_max_allocs: steady_max,
    }
}

/// Lane engine vs boxed per-request stepping on one shard's worth of
/// requests: `requests` identical-config requests step either as ONE
/// struct-of-arrays lane or as `requests` boxed `dyn Solver`s. Model
/// evaluation is excluded from both sides; the reported cost is host
/// nanoseconds per *request-step*, so the ratio is exactly the
/// host-overhead amortisation the lane layer buys. `same_seed` pins
/// every request to one seed (identical data ⇒ identical `delta_eps`
/// ⇒ no ERA lane splits — the steady state the zero-alloc gate pins).
///
/// With `recorder`, every lane step also records the span events the
/// production scheduler emits (solver step, ERA selection, slab
/// completion) *inside* the counted windows — the zero-alloc gate then
/// covers flight recording itself.
fn measure_lane_shard(
    name: &str,
    requests: usize,
    rows: usize,
    nfe: usize,
    trials: usize,
    same_seed: bool,
    recorder: Option<&FlightRecorder>,
) -> (StepCost, StepCost) {
    let sched = VpSchedule::default();
    let model = AnalyticGmm::gmm8(sched);
    let kind = SolverKind::parse(name).unwrap();
    let steps = kind.steps_for_nfe(nfe);
    let warmup = match &kind {
        SolverKind::Era { k, .. } => k + 4,
        SolverKind::Pndm | SolverKind::Fon => 14,
        _ => 6,
    };
    let grid = make_grid(&sched, GridKind::Uniform, steps, 1.0, 1e-3);
    let plan = Arc::new(kind.make_plan(sched, grid, nfe));
    let seed_of = |r: usize| if same_seed { 7 } else { 7 + r as u64 };

    // ---- lane path: one lane, one fused advance per shard step ----
    let mut lane_ns = 0u128;
    let mut lane_steps = 0usize;
    let mut lane_allocs_sum = 0u64;
    let mut lane_counted = 0usize;
    let mut lane_max_allocs = 0u64;
    for trial in 0..=trials {
        let warm_trial = trial == 0;
        let mut eng = LaneEngine::new(0);
        for r in 0..requests {
            let mut rng = Rng::for_stream(seed_of(r), 0x5eed);
            let x0 = rng.normal_tensor(rows, 2);
            let res = kind.resolve_task(plan.clone(), x0, &TaskSpec::default()).unwrap();
            eng.admit(
                r,
                "gmm8",
                LaneAdmission {
                    kind: kind.clone(),
                    view: res.view,
                    x: res.x,
                    churn: res.churn,
                    guided: res.guided,
                    seed: seed_of(r),
                },
            );
        }
        let mut affected: Vec<usize> = Vec::new();
        let mut t_buf: Vec<f32> = Vec::new();
        let mut step = 0usize;
        loop {
            let mut progressed = false;
            for id in 0..eng.lane_slots() {
                if !eng.has_lane(id) {
                    continue;
                }
                if eng.is_done(id) {
                    for rem in eng.finish_lane(id) {
                        black_box(rem.samples.as_slice()[0]);
                    }
                    continue;
                }
                progressed = true;
                let a0 = allocs();
                let t0 = Instant::now();
                affected.clear();
                eng.step_lane(id, &mut affected);
                if let Some(rec) = recorder {
                    for &lid in &affected {
                        rec.record(
                            lid as u64,
                            SpanKind::SolverStep { lane: lid as u32, step: step as u32 },
                        );
                    }
                }
                let ns_step = t0.elapsed().as_nanos();
                let a1 = allocs();
                let (x, t) = match eng.pending(id) {
                    Some(req) => (Arc::clone(&req.x), req.t),
                    None => continue,
                };
                t_buf.clear();
                t_buf.resize(x.rows(), t as f32);
                let eps = model.eval(&x, &t_buf);
                drop(x);
                let a2 = allocs();
                let t1 = Instant::now();
                eng.deliver(id, eps);
                if let Some(rec) = recorder {
                    rec.record(
                        id as u64,
                        SpanKind::SlabComplete {
                            seq: step as u64,
                            round: step as u64,
                            executor: 0,
                            eval_nanos: 0,
                        },
                    );
                    if let Some((_, idx)) = eng.era_selection(id) {
                        let (k, bases) = pack_bases(idx);
                        rec.record(
                            id as u64,
                            SpanKind::EraStep {
                                lane: id as u32,
                                step: step as u32,
                                delta_eps: 0.0,
                                k,
                                bases,
                            },
                        );
                    }
                }
                let ns_on = t1.elapsed().as_nanos();
                let a3 = allocs();
                if !warm_trial && step >= warmup {
                    lane_ns += ns_step + ns_on;
                    lane_steps += requests;
                    let spent = (a1 - a0) + (a3 - a2);
                    lane_allocs_sum += spent;
                    lane_counted += 1;
                    lane_max_allocs = lane_max_allocs.max(spent);
                }
            }
            step += 1;
            if !progressed {
                break;
            }
        }
    }
    let rec_tag = if recorder.is_some() { "+recording" } else { "" };
    let lane = StepCost {
        label: format!("lanes/{name}{rec_tag} {requests}x{rows}rows"),
        steps: lane_steps,
        ns_per_step: lane_ns as f64 / lane_steps.max(1) as f64,
        allocs_per_step: lane_allocs_sum as f64 / lane_counted.max(1) as f64,
        steady_max_allocs: lane_max_allocs,
    };

    // ---- boxed path: one dyn Solver per request, stepped in turn ----
    let mut boxed_ns = 0u128;
    let mut boxed_steps = 0usize;
    for trial in 0..=trials {
        let warm_trial = trial == 0;
        let mut solvers: Vec<Box<dyn Solver>> = (0..requests)
            .map(|r| {
                let mut rng = Rng::for_stream(seed_of(r), 0x5eed);
                let x0 = rng.normal_tensor(rows, 2);
                kind.build_task(plan.clone(), x0, seed_of(r), &TaskSpec::default()).unwrap()
            })
            .collect();
        let mut t_buf: Vec<f32> = Vec::new();
        let mut step = 0usize;
        loop {
            let mut progressed = false;
            for s in solvers.iter_mut() {
                let t0 = Instant::now();
                let req = match s.next_eval() {
                    Some(r) => r,
                    None => continue,
                };
                let ns_next = t0.elapsed().as_nanos();
                progressed = true;
                t_buf.clear();
                t_buf.resize(req.x.rows(), req.t as f32);
                let eps = model.eval(&req.x, &t_buf);
                drop(req);
                let t1 = Instant::now();
                s.on_eval(eps);
                let ns_on = t1.elapsed().as_nanos();
                if !warm_trial && step >= warmup {
                    boxed_ns += ns_next + ns_on;
                    boxed_steps += 1;
                }
            }
            step += 1;
            if !progressed {
                break;
            }
        }
        for s in &solvers {
            black_box(s.current().as_slice()[0]);
        }
    }
    let boxed = StepCost {
        label: format!("boxed/{name} {requests}x{rows}rows"),
        steps: boxed_steps,
        ns_per_step: boxed_ns as f64 / boxed_steps.max(1) as f64,
        allocs_per_step: 0.0,
        steady_max_allocs: 0,
    };
    (lane, boxed)
}

/// Coordinator-layer host overhead: wall time per request through a
/// pool over an instant model at 1/2/4 shards (batching, packing,
/// scatter, plan-cache admission — no device cost to hide behind).
fn measure_pool(shards: usize, requests: usize, rows: usize, nfe: usize) -> f64 {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> =
        Arc::new(MockBank::new(sched).with("gmm8", Box::new(AnalyticGmm::gmm8(sched))));
    let pool = WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::RoundRobin,
            shard: CoordinatorConfig::default(),
            max_inflight_rows: 0,
        },
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            pool.submit(RequestSpec {
                n_samples: rows,
                nfe,
                seed: i as u64,
                ..Default::default()
            })
            .expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("sample");
    }
    let elapsed = t0.elapsed();
    pool.shutdown();
    elapsed.as_secs_f64() * 1e9 / (requests * nfe) as f64
}

/// Model with a configurable dimension and a cheap closed-form eps.
/// The resident-lane wire-cost probe needs the same op stream at
/// different tensor dims, which the dim-2 analytic GMM can't provide.
struct WideModel {
    dim: usize,
}

impl EpsModel for WideModel {
    fn eval(&self, x: &Tensor, t: &[f32]) -> Tensor {
        let mut out = x.clone();
        for (r, &tv) in t.iter().enumerate() {
            for v in out.row_mut(r) {
                *v = 0.5 * *v + 0.1 * tv;
            }
        }
        out
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

/// Total host<->engine bytes (`Telemetry::host_bytes_transferred`) for
/// one request sampled on a residency-enabled bank.
fn resident_bytes(dim: usize, rows: usize, nfe: usize) -> u64 {
    let sched = VpSchedule::default();
    let bank: Arc<dyn ModelBank> = Arc::new(
        MockBank::new(sched).with("wide", Box::new(WideModel { dim })).with_residency(),
    );
    let c = Coordinator::start(bank, CoordinatorConfig::default());
    c.sample(RequestSpec {
        dataset: "wide".into(),
        solver: "era".into(),
        n_samples: rows,
        nfe,
        seed: 11,
        ..Default::default()
    })
    .expect("resident sample");
    let bytes = c.telemetry().host_bytes_transferred.load(Ordering::Relaxed);
    c.shutdown();
    bytes
}

/// ns per invocation of `f` over `passes` timed calls (quarter of that
/// again as untimed warmup).
#[cfg(feature = "simd")]
fn time_passes<F: FnMut()>(mut f: F, passes: usize) -> f64 {
    for _ in 0..passes / 4 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..passes {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e9 / passes as f64
}

/// The scalar-tier twin of `fused::mean_row_dist`, assembled from
/// `fused::scalar::row_sq_dist` so the bench times the reference
/// reduction without going through the dispatched wrapper.
#[cfg(feature = "simd")]
fn scalar_mean_row_dist(a: &[f32], b: &[f32], rows: usize, cols: usize) -> f32 {
    let mut acc = 0.0f64;
    for r in 0..rows {
        let (ra, rb) = (&a[r * cols..(r + 1) * cols], &b[r * cols..(r + 1) * cols]);
        acc += era_solver::kernels::fused::scalar::row_sq_dist(ra, rb).sqrt();
    }
    (acc / rows as f64) as f32
}

/// Time the dispatched fused kernels (SSE2 under `--features simd`)
/// against the always-built scalar tier on the gate shape (dim 256)
/// and return the best scalar/simd ratio across kernels.
///
/// The scalar tier's iterator zips auto-vectorise on x86_64 (SSE2 is
/// the baseline target), so the elementwise kernels can tie; the
/// reduction (`mean_row_dist`'s sequential f64 fold, which the
/// compiler must not reassociate) is where the explicit tier wins.
/// As with the naive-ERA speedup, the max across kernels is the
/// stable signal — per-kernel ratios wobble with runner noise.
#[cfg(feature = "simd")]
fn measure_simd_speedup(quick: bool) -> f64 {
    use era_solver::kernels::fused;

    let (rows, cols) = (64usize, 256usize);
    let len = rows * cols;
    let passes = if quick { 400 } else { 4000 };
    let mut rng = Rng::new(0x51);
    let mut base = vec![0.0f32; len];
    rng.fill_normal(&mut base);
    let mut x = vec![0.0f32; len];
    rng.fill_normal(&mut x);

    let mut best = 0.0f64;
    let mut report_pair = |label: &str, scalar_ns: f64, simd_ns: f64| {
        let ratio = scalar_ns / simd_ns.max(1e-9);
        println!(
            "BENCHLINE step_overhead/simd-{label} scalar_ns={scalar_ns:.0} \
             simd_ns={simd_ns:.0} ratio={ratio:.2}"
        );
        best = best.max(ratio);
    };

    // axpy with an alternating sign keeps the accumulator bounded over
    // thousands of passes.
    let mut out = base.clone();
    let mut s = 0.25f32;
    let sc = time_passes(
        || {
            fused::scalar::axpy(&mut out, s, &x);
            s = -s;
            black_box(&out);
        },
        passes,
    );
    let mut out = base.clone();
    let mut s = 0.25f32;
    let sd = time_passes(
        || {
            fused::axpy(&mut out, s, &x);
            s = -s;
            black_box(&out);
        },
        passes,
    );
    report_pair("axpy", sc, sd);

    // affine_inplace contracts toward `x`, so it is self-bounding.
    let mut out = base.clone();
    let sc = time_passes(
        || {
            fused::scalar::affine_inplace(&mut out, 0.75, 0.25, &x);
            black_box(&out);
        },
        passes,
    );
    let mut out = base.clone();
    let sd = time_passes(
        || {
            fused::affine_inplace(&mut out, 0.75, 0.25, &x);
            black_box(&out);
        },
        passes,
    );
    report_pair("affine", sc, sd);

    // Eq. 15's reduction.
    let sc = time_passes(
        || {
            black_box(scalar_mean_row_dist(&base, &x, rows, cols));
        },
        passes,
    );
    let sd = time_passes(
        || {
            black_box(fused::mean_row_dist(&base, &x, rows, cols));
        },
        passes,
    );
    report_pair("row-dist", sc, sd);

    println!("BENCHLINE step_overhead/simd-speedup ratio={best:.2} (target >= 1.2)");
    // Like the naive-ERA gate above, the timing ratio is only reliable
    // in the full run; quick mode reports it for trend tracking, and
    // the bitwise simd-vs-scalar proptests carry the correctness gate.
    if !quick {
        assert!(
            best >= 1.2,
            "simd kernel speedup {best:.2} fell below the 1.2x target at dim {cols}"
        );
    }
    best
}

fn main() {
    let quick = std::env::var("ERA_BENCH_QUICK").is_ok();
    let trials = if quick { 3 } else { 20 };
    let rows = 256;
    let nfe = 32;

    println!("-- per-step host overhead (model excluded), rows={rows}, nfe={nfe} --");
    let mut era_costs: Vec<StepCost> = Vec::new();
    // k = 5 and 6 cover the k > 4 ERA variants: the zero-alloc gate
    // below holds for them too (selection scratch + Lagrange memo, no
    // per-step Vec).
    for k in 2..=6 {
        let c = measure_solver(&format!("era-{k}"), rows, nfe, trials);
        println!("{}", c.line());
        era_costs.push(c);
    }
    for name in ["ddim", "ddpm", "iadams", "dpm-3", "dpm-fast", "pndm"] {
        let c = measure_solver(name, rows, nfe, trials);
        println!("{}", c.line());
    }

    println!("-- workload step paths (guided paired-row combine, stochastic churn) --");
    let guided = TaskSpec { guidance_scale: 2.0, guide_class: 3, ..Default::default() };
    let mut workload_costs: Vec<StepCost> = Vec::new();
    for name in ["era-4", "ddim"] {
        let c = measure_task_solver(name, rows, nfe, trials, &guided);
        println!("{}", c.line());
        workload_costs.push(c);
    }
    let sde = TaskSpec { churn: 0.4, ..Default::default() };
    let c = measure_task_solver("era-4", rows, nfe, trials, &sde);
    println!("{}", c.line());
    workload_costs.push(c);
    // Acceptance (workload satellite): the paired-row guided combine and
    // the churn injection must not allocate in the steady state either.
    for c in &workload_costs {
        assert_eq!(
            c.steady_max_allocs, 0,
            "{}: workload steady-state step must not allocate",
            c.label
        );
    }

    println!("-- simulated pre-refactor ERA step (allocating path) --");
    let mut best_speedup = 0.0f64;
    for k in 2..=6 {
        let naive = measure_naive_era(rows, k, nfe, trials);
        println!("{}", naive.line());
        let new = &era_costs[k - 2];
        let speedup = naive.ns_per_step / new.ns_per_step.max(1.0);
        best_speedup = best_speedup.max(speedup);
        println!(
            "BENCHLINE step_overhead/era-{k}-speedup ratio={speedup:.2} \
             (target >= 1.5), steady_allocs new={} old~{:.1}",
            new.steady_max_allocs, naive.allocs_per_step
        );
    }

    // Acceptance: zero steady-state heap allocations per ERA step, and
    // host overhead reduced >= 1.5x vs the pre-refactor step shape (the
    // max across orders — per-k ratios wobble with runner noise, a real
    // regression sinks all of them).
    for c in &era_costs {
        assert_eq!(
            c.steady_max_allocs, 0,
            "{}: ERA steady-state step must not allocate",
            c.label
        );
    }
    // The timing ratio is only a reliable gate in the full run (quick
    // mode's 3 trials are noise-dominated on shared CI runners — there
    // the deterministic zero-alloc assertion above is the gate, and the
    // ratio is reported via BENCHLINE for trend tracking).
    if !quick {
        assert!(
            best_speedup >= 1.5,
            "per-step host overhead speedup {best_speedup:.2} fell below the 1.5x target"
        );
    }

    println!("-- lane engine vs boxed per-request stepping, 64-request shard --");
    let mut lane_ratio_ddim = 0.0f64;
    let mut era_lane_ns = 0.0f64;
    for (name, same_seed) in [("ddim", false), ("era-4", true)] {
        let (lane, boxed) = measure_lane_shard(name, 64, 4, nfe, trials, same_seed, None);
        println!("{}", lane.line());
        println!("{}", boxed.line());
        let ratio = boxed.ns_per_step / lane.ns_per_step.max(1.0);
        println!("BENCHLINE step_overhead/lanes-{name} ratio={ratio:.2} (target >= 1.5)");
        // Acceptance: a steady-state lane step performs zero heap
        // allocations, for plain and ERA lanes alike.
        assert_eq!(
            lane.steady_max_allocs, 0,
            "{}: steady-state lane step must not allocate",
            lane.label
        );
        if name == "ddim" {
            lane_ratio_ddim = ratio;
        } else {
            era_lane_ns = lane.ns_per_step;
        }
    }
    // Acceptance (runs in quick mode too — the margin is large enough
    // to survive shared-runner noise): batch-major lanes must cut the
    // per-request host overhead of a 64-request shard by >= 1.5x vs
    // stepping 64 boxed solvers.
    assert!(
        lane_ratio_ddim >= 1.5,
        "lane-vs-boxed host overhead ratio {lane_ratio_ddim:.2} fell below the 1.5x target"
    );

    println!("-- lane stepping with flight recording enabled --");
    // The production scheduler records spans around every lane step; the
    // zero-alloc gate must hold with those hooks in the counted windows.
    let recorder = FlightRecorder::new();
    let (lane_rec, _) = measure_lane_shard("era-4", 64, 4, nfe, trials, true, Some(&recorder));
    println!("{}", lane_rec.line());
    assert!(recorder.recorded() > 0, "recorder saw no events");
    assert_eq!(
        lane_rec.steady_max_allocs, 0,
        "{}: steady-state lane step with recording enabled must not allocate",
        lane_rec.label
    );

    println!("-- coordinator host overhead per step, instant model --");
    let reqs = if quick { 4 } else { 16 };
    let mut pool_ns = [0.0f64; 3];
    for (i, shards) in [1usize, 2, 4].into_iter().enumerate() {
        let ns = measure_pool(shards, reqs, 64, 10);
        pool_ns[i] = ns;
        println!(
            "BENCHLINE step_overhead/pool shards={shards} ns_per_request_step={ns:.0}"
        );
    }

    println!("-- fused kernel tiers: dispatched vs scalar reference, dim=256 --");
    #[cfg(feature = "simd")]
    let simd_speedup = measure_simd_speedup(quick);
    #[cfg(not(feature = "simd"))]
    println!("BENCHLINE step_overhead/simd-speedup skipped (built without the `simd` feature)");

    println!("-- resident-lane wire cost: marginal bytes per step vs dim --");
    // Marginal per-step cost: two runs at the same rows/dim differing
    // only in NFE isolate the steady-state (op, outcome) pair — the
    // one-time upload and the finish gather cancel in the difference.
    let r_rows = 32;
    let resident_per_step = |dim: usize| {
        let lo = resident_bytes(dim, r_rows, 10);
        let hi = resident_bytes(dim, r_rows, 22);
        (hi - lo) as f64 / 12.0
    };
    let bytes_d64 = resident_per_step(64);
    let bytes_d512 = resident_per_step(512);
    println!(
        "BENCHLINE step_overhead/resident-bytes rows={r_rows} dim64_per_step={bytes_d64:.1} \
         dim512_per_step={bytes_d512:.1}"
    );
    // Acceptance (deterministic byte accounting, so it gates in quick
    // mode too): a resident lane's marginal per-step wire cost must not
    // scale with the tensor dimension — the slab path ships the full
    // iterate out and the full eps back (2 * rows * dim * 4 bytes) on
    // every step; the resident path ships plan coefficients out and
    // per-row distances back.
    assert!(
        (bytes_d512 / bytes_d64 - 1.0).abs() < 0.01,
        "resident per-step bytes scaled with dim: {bytes_d64:.1} @ dim 64 \
         vs {bytes_d512:.1} @ dim 512"
    );
    let slab_per_step = (2 * r_rows * 512 * 4) as f64;
    assert!(
        bytes_d512 * 4.0 < slab_per_step,
        "resident per-step bytes {bytes_d512:.1} not well below the dim-512 slab \
         cost {slab_per_step:.0}"
    );

    // Structured perf-trajectory artifact (BENCH_step_overhead.json when
    // $ERA_BENCH_JSON_DIR is set). Alloc counts and ratios are
    // machine-independent and gate CI against benchmarks/ baselines;
    // raw timings ride along for trend tracking only (the committed
    // baselines deliberately omit them).
    let era_alloc_max = era_costs.iter().map(|c| c.steady_max_allocs).max().unwrap_or(0);
    let wl_alloc_max = workload_costs.iter().map(|c| c.steady_max_allocs).max().unwrap_or(0);
    let mut report = BenchReport::new("step_overhead");
    report.push("era_steady_max_allocs", era_alloc_max as f64, Direction::LowerIsBetter, 0.0);
    report.push("workload_steady_max_allocs", wl_alloc_max as f64, Direction::LowerIsBetter, 0.0);
    report.push(
        "recorded_lane_steady_max_allocs",
        lane_rec.steady_max_allocs as f64,
        Direction::LowerIsBetter,
        0.0,
    );
    report.push("lanes_ddim_ratio", lane_ratio_ddim, Direction::HigherIsBetter, 0.0);
    report.push("era_speedup_vs_naive", best_speedup, Direction::HigherIsBetter, 0.35);
    report.push("era4_ns_per_step", era_costs[2].ns_per_step, Direction::LowerIsBetter, 1.0);
    report.push("era4_lane_ns_per_request_step", era_lane_ns, Direction::LowerIsBetter, 1.0);
    report.push(
        "recorded_lane_ns_per_request_step",
        lane_rec.ns_per_step,
        Direction::LowerIsBetter,
        1.0,
    );
    report.push("pool_1shard_ns_per_request_step", pool_ns[0], Direction::LowerIsBetter, 1.0);
    report.push("pool_4shard_ns_per_request_step", pool_ns[2], Direction::LowerIsBetter, 1.0);
    // Deterministic byte accounting (dim 512, 32 rows): gated against
    // the committed baseline. `simd_speedup` only exists in simd builds
    // — CI runs the regression gate on the simd leg alone so the
    // scalar leg's report never misses a baseline metric.
    report.push("host_bytes_per_step", bytes_d512, Direction::LowerIsBetter, 0.1);
    #[cfg(feature = "simd")]
    report.push("simd_speedup", simd_speedup, Direction::HigherIsBetter, 0.4);
    report.write_if_env();
    println!("done");
}
