"""Layer-2 checks: denoiser shapes, init behaviour, Pallas/oracle parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, eps_theta, init_params, param_count


@pytest.fixture(scope="module")
def small():
    cfg = ModelConfig(dim=2, width=32, n_blocks=2, temb_dim=16, temb_hidden=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


class TestShapes:
    @pytest.mark.parametrize("batch", [1, 7, 64])
    def test_output_shape(self, small, batch):
        params, cfg = small
        x = jnp.ones((batch, cfg.dim))
        t = jnp.full((batch,), 0.5)
        out = eps_theta(params, cfg, x, t, use_pallas=False)
        assert out.shape == (batch, cfg.dim)

    def test_dim64(self):
        cfg = ModelConfig(dim=64, width=64, n_blocks=2, temb_dim=16, temb_hidden=32)
        params = init_params(jax.random.PRNGKey(1), cfg)
        out = eps_theta(params, cfg, jnp.ones((4, 64)), jnp.full((4,), 0.3),
                        use_pallas=False)
        assert out.shape == (4, 64)


class TestInit:
    def test_zero_output_head(self, small):
        """Output head is zero-initialised: eps_hat == 0 at init."""
        params, cfg = small
        out = eps_theta(params, cfg, jnp.ones((8, 2)), jnp.full((8,), 0.5),
                        use_pallas=False)
        np.testing.assert_allclose(out, 0.0, atol=1e-7)

    def test_param_count(self, small):
        params, cfg = small
        n = param_count(params)
        # in_proj + temb1 + out + blocks + films, computed by hand:
        w, th, td, d, nb = cfg.width, cfg.temb_hidden, cfg.temb_dim, cfg.dim, cfg.n_blocks
        expect = (d * w + w) + (td * th + th) + (w * d + d)
        expect += nb * (2 * (w * w + w)) + nb * (th * 2 * w + 2 * w)
        assert n == expect


class TestParity:
    """The exported artifact runs the Pallas path; training ran the oracle
    path. They must be numerically identical (modulo float assoc)."""

    @pytest.mark.parametrize("batch", [1, 16, 50])
    def test_pallas_vs_oracle(self, small, batch):
        params, cfg = small
        key = jax.random.PRNGKey(batch)
        x = jax.random.normal(key, (batch, cfg.dim))
        t = jax.random.uniform(key, (batch,), minval=1e-4, maxval=1.0)
        a = eps_theta(params, cfg, x, t, use_pallas=True)
        b = eps_theta(params, cfg, x, t, use_pallas=False)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_time_dependence_after_perturbation(self, small):
        """Perturb the FiLM head: output must depend on t (the init is
        deliberately time-independent, so check the wiring, not the init)."""
        params, cfg = small
        params = jax.tree_util.tree_map(lambda p: p + 0.05, params)
        x = jnp.ones((4, cfg.dim))
        o1 = eps_theta(params, cfg, x, jnp.full((4,), 0.1), use_pallas=False)
        o2 = eps_theta(params, cfg, x, jnp.full((4,), 0.9), use_pallas=False)
        assert float(jnp.abs(o1 - o2).max()) > 1e-4
