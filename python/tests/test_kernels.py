"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes (and weight patterns) so the kernels are pinned
to the references across the whole envelope the AOT pipeline exports.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_resmlp import (
    DEFAULT_BLOCK_B,
    fused_resmlp,
    mxu_flops,
    pick_block_b,
    vmem_bytes,
)
from compile.kernels.ref import fused_resmlp_ref, solver_combine_ref, time_embed_ref
from compile.kernels.solver_combine import (
    K_MAX,
    era_combine_weights,
    hbm_bytes,
    solver_combine,
)


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _resmlp_inputs(seed, b, w):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    return (
        _rand(ks[0], b, w),
        _rand(ks[1], b, w, scale=0.2),
        _rand(ks[2], b, w, scale=0.2),
        _rand(ks[3], w, w, scale=0.1),
        _rand(ks[4], w),
        _rand(ks[5], w, w, scale=0.1),
        _rand(ks[6], w),
    )


class TestFusedResMlp:
    @pytest.mark.parametrize("b", [1, 2, 16, 48, 64, 100])
    @pytest.mark.parametrize("w", [8, 128])
    def test_matches_ref(self, b, w):
        args = _resmlp_inputs(0, b, w)
        out = fused_resmlp(*args)
        ref = fused_resmlp_ref(*args)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(min_value=1, max_value=96),
        w=st.sampled_from([4, 16, 64, 128]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, w, seed):
        args = _resmlp_inputs(seed, b, w)
        out = fused_resmlp(*args)
        ref = fused_resmlp_ref(*args)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_block_b_divides(self):
        for batch in range(1, 300):
            bb = pick_block_b(batch)
            assert batch % bb == 0
            assert 1 <= bb <= min(batch, DEFAULT_BLOCK_B)

    def test_zero_film_is_plain_resmlp(self):
        """scale=shift=0 must reduce to an unmodulated residual block."""
        h, _, _, w1, b1, w2, b2 = _resmlp_inputs(3, 32, 64)
        z = jnp.zeros_like(h)
        out = fused_resmlp(h, z, z, w1, b1, w2, b2)
        mid = jax.nn.silu(h @ w1 + b1)
        np.testing.assert_allclose(out, h + mid @ w2 + b2, atol=1e-4, rtol=1e-4)

    def test_vmem_estimate_monotone(self):
        assert vmem_bytes(64, 128) < vmem_bytes(64, 256) < vmem_bytes(128, 512)
        # Default config fits comfortably in a 16 MiB VMEM budget.
        assert vmem_bytes(DEFAULT_BLOCK_B, 512) < 16 * 2**20

    def test_mxu_flops(self):
        assert mxu_flops(64, 128) == 2 * 2 * 64 * 128 * 128


class TestSolverCombine:
    def _inputs(self, seed, k, b, d):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        return (
            _rand(ks[0], k, b, d),
            _rand(ks[1], k),
            _rand(ks[2], b, d),
            jnp.asarray(jax.random.normal(ks[3], (2,))),
        )

    @pytest.mark.parametrize("k", [1, 3, 4, 6, K_MAX])
    @pytest.mark.parametrize("b,d", [(1, 2), (16, 2), (64, 64), (100, 3)])
    def test_matches_ref(self, k, b, d):
        args = self._inputs(0, k, b, d)
        out = solver_combine(*args)
        ref = solver_combine_ref(*args)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(min_value=1, max_value=K_MAX),
        b=st.integers(min_value=1, max_value=64),
        d=st.integers(min_value=1, max_value=16),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, k, b, d, seed):
        args = self._inputs(seed, k, b, d)
        out = solver_combine(*args)
        ref = solver_combine_ref(*args)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_zero_padded_slots_inert(self):
        """Zero weights on padded buffer slots must not change the result."""
        eps_buf, w, x, ab = self._inputs(1, 4, 32, 2)
        pad = jnp.zeros((K_MAX - 4, 32, 2))
        eps_pad = jnp.concatenate([eps_buf, 1e6 * jnp.ones_like(pad)], axis=0)
        w_pad = jnp.concatenate([w, jnp.zeros((K_MAX - 4,))])
        out = solver_combine(eps_pad, w_pad, x, ab)
        ref = solver_combine(eps_buf, w, x, ab)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_identity_update(self):
        """a=1, b=0 is a no-op on x."""
        eps_buf, w, x, _ = self._inputs(2, 3, 16, 2)
        out = solver_combine(eps_buf, w, x, jnp.array([1.0, 0.0]))
        np.testing.assert_allclose(out, x, atol=1e-6)

    def test_hbm_estimate(self):
        assert hbm_bytes(4, 256, 2) == 6 * 256 * 2 * 4


class TestEraCombineWeights:
    """The collapsed predictor+corrector weights must reproduce the
    explicit two-stage ERA update (Eq. 13/14 then Eq. 11)."""

    def _two_stage(self, eps_buf, idx, lw, amw, x, ab):
        n = eps_buf.shape[0]
        pred = sum(w * eps_buf[j] for j, w in zip(idx, lw))
        comb = amw[0] * pred
        for m in range(len(amw) - 1):
            comb = comb + amw[1 + m] * eps_buf[n - 1 - m]
        return ab[0] * x + ab[1] * comb

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=K_MAX),
        b=st.integers(min_value=1, max_value=48),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        data=st.data(),
    )
    def test_collapse_matches_two_stage(self, n, b, seed, data):
        k = data.draw(st.integers(min_value=1, max_value=n))
        c = data.draw(st.integers(min_value=1, max_value=min(n, 4)))
        idx = data.draw(
            st.lists(
                st.integers(min_value=0, max_value=n - 1),
                min_size=k, max_size=k, unique=True,
            )
        )
        rng = np.random.default_rng(seed)
        lw = rng.normal(size=k).tolist()
        amw = rng.normal(size=1 + c).tolist()
        eps_buf = jnp.asarray(rng.normal(size=(n, b, 2)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)
        ab = jnp.asarray(rng.normal(size=(2,)), jnp.float32)

        w = jnp.asarray(era_combine_weights(idx, lw, amw, n), jnp.float32)
        out = solver_combine(eps_buf, w, x, ab)
        ref = self._two_stage(eps_buf, idx, lw, amw, x, ab)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_k_max_padding_is_inert(self):
        n, idx, lw, amw = 3, [0, 2], [0.75, 0.25], [0.5, 0.5]
        w = era_combine_weights(idx, lw, amw, n, k_max=K_MAX)
        assert len(w) == K_MAX
        assert w[n:] == [0.0] * (K_MAX - n)
        assert w[:n] == era_combine_weights(idx, lw, amw, n)

    def test_corrector_folds_onto_selected_buffer(self):
        # Buffer 2 is both a Lagrange point and the newest corrector
        # term: the weights must sum, not overwrite.
        w = era_combine_weights([2], [0.5], [2.0, 0.25], 3)
        assert w == [0.0, 0.0, 2.0 * 0.5 + 0.25]

    def test_rejects_malformed_coefficients(self):
        with pytest.raises(ValueError):
            era_combine_weights([0], [1.0, 2.0], [1.0], 2)
        with pytest.raises(ValueError):
            era_combine_weights([0], [1.0], [], 2)
        with pytest.raises(ValueError):
            era_combine_weights([5], [1.0], [1.0], 2)
        with pytest.raises(ValueError):
            era_combine_weights([0], [1.0], [1.0, 0.5, 0.5], 1)
        with pytest.raises(ValueError):
            era_combine_weights([0], [1.0], [1.0], 4, k_max=2)


class TestTimeEmbed:
    def test_shape_and_range(self):
        t = jnp.linspace(0.0, 1.0, 33)
        emb = time_embed_ref(t, 64)
        assert emb.shape == (33, 64)
        assert float(jnp.abs(emb).max()) <= 1.0 + 1e-6

    def test_distinguishes_times(self):
        emb = time_embed_ref(jnp.array([0.1, 0.9]), 32)
        assert float(jnp.linalg.norm(emb[0] - emb[1])) > 0.1
