"""Training + AOT export smoke tests (short runs; full runs happen at
`make artifacts`)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import _schedule_probe, export_combine, export_eps, to_hlo_text
from compile.diffusion import VpSchedule
from compile.model import ModelConfig, eps_theta, init_params
from compile.train import TrainConfig, train


@pytest.fixture(scope="module")
def trained():
    """A briefly-trained gmm8 model shared across the module's tests."""
    return train("gmm8", tcfg=TrainConfig(steps=120, batch=128, err_samples=512,
                                          err_bins=8), verbose=False)


class TestTrain:
    def test_loss_decreases(self, trained):
        _, _, report = trained
        curve = report["loss_curve"]
        assert curve[-1] < curve[0]
        assert report["final_loss"] < 1.5  # eps-MSE starts ~2 for this data

    def test_error_curve_shape(self, trained):
        """Paper Fig. 1 premise: estimation error grows as t -> 0."""
        _, _, report = trained
        err = report["error_curve"]["err"]
        assert len(err) == 8
        assert err[0] > err[-1]  # low-t bin worse than high-t bin

    def test_report_fields(self, trained):
        _, _, report = trained
        for field in ("dataset", "loss_curve", "param_count", "error_curve"):
            assert field in report
        json.dumps(report)  # must be JSON-serialisable as written


class TestExport:
    def test_eps_hlo_has_real_constants(self, trained):
        params, mcfg, _ = trained
        text = export_eps(params, mcfg, 16)
        assert "ENTRY" in text
        # The elision bug this guards against: constants printed as {...}.
        assert "constant({...})" not in text
        assert text.count("f32[128,128]") >= 2 * mcfg.n_blocks

    def test_eps_hlo_entry_shapes(self, trained):
        params, mcfg, _ = trained
        text = export_eps(params, mcfg, 8)
        assert "f32[8,2]" in text and "f32[8]" in text

    def test_combine_hlo(self):
        text = export_combine(2, 16)
        assert "ENTRY" in text
        assert "f32[8,16,2]" in text  # K_MAX x batch x dim input

    def test_export_text_reparses(self, trained):
        """The HLO text must parse back into an HloModule (the same parser
        the Rust xla crate invokes). Execution-level validation of the
        round trip lives in rust/tests/integration_runtime.rs."""
        from jax._src.lib import xla_client as xc

        params, mcfg, _ = trained
        text = export_eps(params, mcfg, 4)
        hmod = xc._xla.hlo_module_from_text(text)
        # Re-serialising implies every instruction (incl. the baked weight
        # constants) survived the text round trip.
        assert len(hmod.as_serialized_hlo_module_proto()) > 100_000


class TestScheduleProbe:
    def test_probe_matches_schedule(self):
        probe = _schedule_probe()
        sched = VpSchedule()
        for t, ab in zip(probe["t"], probe["alpha_bar"]):
            np.testing.assert_allclose(float(sched.alpha_bar(jnp.float32(t))), ab,
                                       rtol=1e-6)
        assert all(np.isfinite(probe["log_snr"]))
