"""VP schedule invariants; these same values pin the Rust mirror."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.diffusion import VpSchedule, uniform_times


@pytest.fixture(scope="module")
def sched():
    return VpSchedule()


class TestAlphaBar:
    def test_bounds(self, sched):
        ts = jnp.linspace(1e-5, 1.0, 101)
        ab = sched.alpha_bar(ts)
        assert float(ab.min()) > 0.0
        assert float(ab.max()) < 1.0

    def test_near_identity_at_zero(self, sched):
        assert float(sched.alpha_bar(jnp.float32(1e-6))) == pytest.approx(1.0, abs=1e-4)

    def test_near_zero_at_one(self, sched):
        # VP with beta_max=20: alpha_bar(1) = exp(-(20+0.1)/2) ~ 4e-5.
        assert float(sched.alpha_bar(jnp.float32(1.0))) < 1e-4

    @settings(max_examples=40, deadline=None)
    @given(t1=st.floats(1e-5, 1.0), t2=st.floats(1e-5, 1.0))
    def test_monotone_decreasing(self, sched, t1, t2):
        lo, hi = sorted((t1, t2))
        if hi - lo < 1e-7:
            return
        assert float(sched.alpha_bar(jnp.float32(hi))) <= float(
            sched.alpha_bar(jnp.float32(lo))
        ) + 1e-7

    def test_closed_form_vs_quadrature(self, sched):
        """alpha_bar(t) == exp(-int_0^t beta(s) ds), checked numerically."""
        t = 0.37
        s = np.linspace(0.0, t, 20001)
        beta = sched.beta_min + s * (sched.beta_max - sched.beta_min)
        integral = np.trapezoid(beta, s)
        np.testing.assert_allclose(
            float(sched.alpha_bar(jnp.float32(t))), np.exp(-integral), rtol=1e-4
        )


class TestLogSnr:
    def test_monotone_decreasing(self, sched):
        ts = jnp.linspace(1e-4, 1.0, 200)
        snr = sched.log_snr(ts)
        assert bool(jnp.all(jnp.diff(snr) < 0))

    def test_sigma_sq_complement(self, sched):
        ts = jnp.linspace(1e-4, 1.0, 50)
        np.testing.assert_allclose(
            sched.sigma(ts) ** 2 + sched.alpha_bar(ts), 1.0, atol=1e-6
        )


class TestQSample:
    def test_statistics(self, sched):
        """x_t | x0 has mean sqrt(ab)*x0 and var (1-ab) per coordinate."""
        key = jax.random.PRNGKey(0)
        x0 = jnp.full((20000, 2), 1.5)
        t = jnp.full((20000,), 0.5)
        x_t, eps = sched.q_sample(key, x0, t)
        ab = float(sched.alpha_bar(jnp.float32(0.5)))
        np.testing.assert_allclose(float(x_t.mean()), (ab**0.5) * 1.5, atol=0.02)
        np.testing.assert_allclose(float(x_t.var()), 1 - ab, rtol=0.05)
        np.testing.assert_allclose(float(eps.mean()), 0.0, atol=0.02)

    def test_reconstruction(self, sched):
        """(x_t - sigma*eps)/sqrt(ab) recovers x0 exactly."""
        key = jax.random.PRNGKey(1)
        x0 = jax.random.normal(key, (64, 2))
        t = jnp.full((64,), 0.3)
        x_t, eps = sched.q_sample(key, x0, t)
        rec = (x_t - sched.sigma(t)[:, None] * eps) / sched.sqrt_alpha_bar(t)[:, None]
        np.testing.assert_allclose(rec, x0, atol=1e-5)


def test_uniform_times_range():
    t = uniform_times(jax.random.PRNGKey(0), 10000, t_min=1e-4)
    assert float(t.min()) >= 1e-4
    assert float(t.max()) <= 1.0
