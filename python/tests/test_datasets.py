"""Synthetic manifold sanity checks (shapes, supports, moments)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets


@pytest.mark.parametrize("name", datasets.DATASETS)
def test_shapes_and_dtype(name):
    x = datasets.sample(name, jax.random.PRNGKey(0), 257)
    assert x.shape == (257, datasets.spec(name).dim)
    assert x.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(x)))


def test_unknown_dataset_raises():
    with pytest.raises(KeyError):
        datasets.spec("nope")
    with pytest.raises(KeyError):
        datasets.sample("nope", jax.random.PRNGKey(0), 1)


class TestGmm8:
    def test_modes_on_circle(self):
        x = np.asarray(datasets.sample("gmm8", jax.random.PRNGKey(1), 8000))
        r = np.linalg.norm(x, axis=1)
        # Radius 2 modes with std 0.15 -> nearly all mass in [1.4, 2.6].
        assert (np.abs(r - 2.0) < 0.6).mean() > 0.99

    def test_centered(self):
        x = np.asarray(datasets.sample("gmm8", jax.random.PRNGKey(2), 20000))
        np.testing.assert_allclose(x.mean(axis=0), 0.0, atol=0.05)


class TestCheckerboard:
    def test_support(self):
        x = np.asarray(datasets.sample("checkerboard", jax.random.PRNGKey(3), 20000))
        assert np.all(np.abs(x) <= 2.0 + 1e-5)

    def test_checker_parity(self):
        """All samples land on black cells: floor(x)+floor(y) even."""
        x = np.asarray(datasets.sample("checkerboard", jax.random.PRNGKey(4), 20000))
        cx = np.floor(x[:, 0] + 2.0)
        cy = np.floor(np.clip(x[:, 1] + 2.0, 0, 3.999))
        assert ((cx + cy) % 2 == 0).mean() > 0.995


class TestRings:
    def test_two_radii(self):
        x = np.asarray(datasets.sample("rings", jax.random.PRNGKey(5), 20000))
        r = np.linalg.norm(x, axis=1)
        inner = np.abs(r - 0.8) < 0.3
        outer = np.abs(r - 1.8) < 0.3
        assert (inner | outer).mean() > 0.99
        assert 0.4 < inner.mean() < 0.6  # balanced mixture


class TestPatches64:
    def test_bounded(self):
        x = np.asarray(datasets.sample("patches64", jax.random.PRNGKey(6), 4000))
        assert np.all(np.abs(x) <= 1.0)

    def test_basis_deterministic_and_normalised(self):
        b1 = datasets.patches_basis()
        b2 = datasets.patches_basis()
        np.testing.assert_array_equal(b1, b2)
        np.testing.assert_allclose(np.linalg.norm(b1, axis=0), 1.0, rtol=1e-5)

    def test_low_rank_structure(self):
        x = np.asarray(datasets.sample("patches64", jax.random.PRNGKey(7), 4000))
        # tanh of rank-8 field: spectrum should be dominated by the top
        # ~8 directions.
        s = np.linalg.svd(x - x.mean(0), compute_uv=False)
        assert s[:8].sum() / s.sum() > 0.8


class TestReferenceStats:
    def test_cov_symmetric_psd(self):
        mean, cov = datasets.reference_stats("gmm8", n=20000)
        assert mean.shape == (2,)
        assert cov.shape == (2, 2)
        np.testing.assert_allclose(cov, cov.T, atol=1e-12)
        assert np.all(np.linalg.eigvalsh(cov) > 0)

    def test_gmm8_known_moments(self):
        """8 modes on radius-2 circle: E[x]=0, var = 2 + 0.15^2 per axis."""
        mean, cov = datasets.reference_stats("gmm8", n=100000)
        np.testing.assert_allclose(mean, 0.0, atol=0.03)
        np.testing.assert_allclose(np.diag(cov), 2.0 + 0.15**2, rtol=0.05)
