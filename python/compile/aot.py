"""AOT pipeline: train -> lower -> HLO text artifacts + manifest.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written under artifacts/<dataset>/:
    eps_b<N>.hlo.txt       denoiser eps_theta at batch bucket N, trained
                           weights baked in as HLO constants
    combine_b<N>.hlo.txt   fused solver-update kernel (Layer 1) at bucket N
    train_report.json      loss + Fig.1 noise-error curve
plus artifacts/manifest.json describing everything (the Rust runtime's
registry parses this).

Usage: python -m compile.aot [--datasets a,b,c] [--out-dir ../artifacts]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets
from .diffusion import BETA_MAX, BETA_MIN, VpSchedule
from .kernels.solver_combine import K_MAX, solver_combine
from .model import ModelConfig, eps_theta
from .train import default_model_config, default_train_config, train

#: Batch buckets compiled per model. The Rust batcher rounds every network
#: evaluation up to the nearest bucket and pads (standard serving practice;
#: XLA executables are shape-specialised).
BATCH_BUCKETS = (1, 16, 64, 256)

MANIFEST_VERSION = 3


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True).

    print_large_constants=True is load-bearing: the trained weights are
    closed over as constants, and the default printer elides anything big
    as `constant({...})`, which parses back as garbage on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_eps(params, mcfg: ModelConfig, batch: int) -> str:
    """Lower eps_theta with trained params closed over as constants."""

    def fn(x, t):
        # The exported graph routes through the Pallas kernel (Layer 1);
        # interpret=True lowers it to plain HLO the CPU PJRT client runs.
        return (eps_theta(params, mcfg, x, t, use_pallas=True),)

    x_spec = jax.ShapeDtypeStruct((batch, mcfg.dim), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(x_spec, t_spec))


def export_combine(dim: int, batch: int) -> str:
    """Lower the fused solver-update kernel at one (batch, dim) bucket."""

    def fn(eps_buf, w, x, ab):
        return (solver_combine(eps_buf, w, x, ab),)

    specs = (
        jax.ShapeDtypeStruct((K_MAX, batch, dim), jnp.float32),
        jax.ShapeDtypeStruct((K_MAX,), jnp.float32),
        jax.ShapeDtypeStruct((batch, dim), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _schedule_probe() -> dict:
    """Reference values of alpha_bar(t) so Rust can self-test its mirror."""
    sched = VpSchedule()
    ts = np.linspace(1e-4, 1.0, 17)
    return {
        "t": ts.tolist(),
        "alpha_bar": [float(sched.alpha_bar(t)) for t in ts],
        "log_snr": [float(sched.log_snr(t)) for t in ts],
    }


def _sha256(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def build_dataset(name: str, out_dir: str, buckets=BATCH_BUCKETS) -> dict:
    """Train + export all artifacts for one dataset; returns manifest entry."""
    ds_dir = os.path.join(out_dir, name)
    os.makedirs(ds_dir, exist_ok=True)
    mcfg = default_model_config(name)
    tcfg = default_train_config(name)

    print(f"=== {name}: training (dim={mcfg.dim}, width={mcfg.width}) ===",
          flush=True)
    params, mcfg, report = train(name, mcfg, tcfg)
    with open(os.path.join(ds_dir, "train_report.json"), "w") as f:
        json.dump(report, f)

    entry = {
        "dim": mcfg.dim,
        "model": mcfg.to_json(),
        "stands_in_for": datasets.spec(name).stands_in_for,
        "final_loss": report["final_loss"],
        "eps": {},
        "combine": {},
        "k_max": K_MAX,
    }

    for b in buckets:
        t0 = time.time()
        text = export_eps(params, mcfg, b)
        rel = f"{name}/eps_b{b}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        entry["eps"][str(b)] = {"path": rel, "sha": _sha256(text)}
        print(f"  eps_b{b}: {len(text) / 1e6:.1f} MB in {time.time() - t0:.0f}s",
              flush=True)

        text = export_combine(mcfg.dim, b)
        rel = f"{name}/combine_b{b}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        entry["combine"][str(b)] = {"path": rel, "sha": _sha256(text)}

    mean, cov = datasets.reference_stats(name)
    entry["ref_stats"] = {
        "n": 200_000,
        "mean": mean.tolist(),
        "cov": cov.reshape(-1).tolist(),
    }
    if name == "patches64":
        entry["patches_basis"] = datasets.patches_basis().reshape(-1).tolist()
    return entry


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--datasets", default=",".join(datasets.DATASETS))
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__),
                                                      "..", "..", "artifacts"))
    ap.add_argument("--buckets", default=",".join(map(str, BATCH_BUCKETS)))
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    buckets = tuple(int(b) for b in args.buckets.split(","))

    manifest = {
        "version": MANIFEST_VERSION,
        "jax_version": jax.__version__,
        "created_unix": int(time.time()),
        "schedule": {"kind": "vp", "beta_min": BETA_MIN, "beta_max": BETA_MAX,
                     "probe": _schedule_probe()},
        "batch_buckets": list(buckets),
        "datasets": {},
    }
    for name in args.datasets.split(","):
        manifest["datasets"][name] = build_dataset(name, out_dir, buckets)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}", flush=True)


if __name__ == "__main__":
    main()
