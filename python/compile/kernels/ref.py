"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has an entry here with the identical
signature. pytest (and hypothesis sweeps) assert allclose between the
Pallas interpret-mode kernel and these references — this is the core
correctness signal for Layer 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_resmlp_ref(h, scale, shift, w1, b1, w2, b2):
    """FiLM-modulated residual MLP block (the denoiser's hot block).

        u   = h * (1 + scale) + shift          # FiLM from the time embed
        mid = silu(u @ w1 + b1)
        out = h + mid @ w2 + b2

    Shapes: h, scale, shift (B, W); w1, w2 (W, W); b1, b2 (W,).
    """
    u = h * (1.0 + scale) + shift
    mid = jax.nn.silu(u @ w1 + b1)
    return h + mid @ w2 + b2


def solver_combine_ref(eps_buf, w, x, ab):
    """Fused solver update used by the XLA-offloaded solver path.

        out = a * x + b * sum_k w[k] * eps_buf[k]

    `eps_buf` is the stacked Lagrange/Adams buffer (K, B, D); `w` holds the
    combined predictor/corrector weights (K,), zero-padded to K_max so one
    artifact serves every interpolation order; `ab = [a, b]` carries the
    DDIM transition coefficients of Eq. 8.
    """
    a, b = ab[0], ab[1]
    mixed = jnp.einsum("k,kbd->bd", w, eps_buf)
    return a * x + b * mixed


def time_embed_ref(t, dim):
    """Sinusoidal time embedding (B,) -> (B, dim), dim even."""
    half = dim // 2
    freqs = jnp.exp(jnp.linspace(0.0, jnp.log(1000.0), half))
    ang = t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
