"""Pallas kernel: fused ERA-Solver state update (streaming VPU kernel).

Computes, in one pass over HBM,

    out = a * x + b * sum_k w[k] * eps_buf[k]

which covers every linear solver update in this repo: the Lagrange
predictor (Eq. 13/14), the Adams–Moulton corrector mix (Eq. 11) and the
DDIM transition (Eq. 8) collapse into exactly this affine combination once
the scalar weights are computed (the Rust coordinator computes them; they
depend only on the timestep grid and the selected buffer indices, not on
tensor data).

TPU mapping: no MXU work at all — this is bandwidth-bound. The grid tiles
the (B, D) plane; each step streams K buffer tiles + one x tile from HBM
through VMEM and writes one tile back: (K+1) reads + 1 write, the roofline
minimum. A CUDA version would express the same schedule with threadblocks
over elements; BlockSpec is the TPU-native spelling.

K is padded to K_MAX with zero weights so a single AOT artifact serves all
interpolation orders k <= K_MAX at a fixed (B, D) bucket.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Maximum buffer depth baked into the artifact; the paper ablates k=3..6.
K_MAX = 8

#: Rows per grid step; D is kept whole (it is small for these models).
DEFAULT_BLOCK_B = 256


def _kernel(eps_ref, w_ref, x_ref, ab_ref, o_ref):
    k = eps_ref.shape[0]
    w = w_ref[...]
    a = ab_ref[0]
    b = ab_ref[1]
    # einsum k,kbd->bd on the VPU; unrolled over the (static) buffer depth.
    acc = w[0] * eps_ref[0]
    for i in range(1, k):
        acc = acc + w[i] * eps_ref[i]
    o_ref[...] = a * x_ref[...] + b * acc


def pick_block_b(batch: int, block_b: int = DEFAULT_BLOCK_B) -> int:
    bb = min(batch, block_b)
    while batch % bb != 0:
        bb -= 1
    return bb


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def solver_combine(eps_buf, w, x, ab, *, block_b: int = DEFAULT_BLOCK_B,
                   interpret: bool = True):
    """Fused update; same contract as kernels.ref.solver_combine_ref.

    eps_buf: (K, B, D) stacked noise buffer (K <= K_MAX, zero-padded weights
             make unused slots inert)
    w:       (K,) combination weights
    x:       (B, D) current iterate
    ab:      (2,) = [a, b] transition coefficients
    """
    k, batch, dim = eps_buf.shape
    assert w.shape == (k,)
    bb = pick_block_b(batch, block_b)
    grid = (batch // bb,)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, bb, dim), lambda i: (0, i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((bb, dim), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), x.dtype),
        interpret=interpret,
    )(eps_buf, w, x, ab)


def hbm_bytes(k: int, batch: int, dim: int, dtype_bytes: int = 4) -> int:
    """Roofline traffic: (k+1) tile reads + 1 write (for §Perf)."""
    return (k + 2) * batch * dim * dtype_bytes


def era_combine_weights(idx, lw, amw, n, k_max=None):
    """Collapse ERA's two-stage update into one per-buffer weight vector.

    The Rust solver ships a resident ERA step as the triple
    ``(idx, lw, amw)``: Lagrange predictor weights ``lw`` over the eps
    buffers named by ``idx`` (Eq. 13/14), folded through Adams–Moulton
    corrector weights ``amw`` (Eq. 11) where ``amw[0]`` scales the
    predictor and ``amw[1 + m]`` scales buffer ``n - 1 - m``. Because
    both stages are linear in the history, they flatten to a single
    weight per buffer:

        w[idx[j]]   += amw[0] * lw[j]
        w[n - 1 - m] += amw[1 + m]

    which is exactly the ``w`` argument :func:`solver_combine` streams
    — the fused kernel then applies the whole predictor-corrector step
    in one pass over HBM. Weights stay float64 here (the plan's native
    dtype, matching the Rust side) and narrow to f32 only when the
    kernel input arrays are built.

    idx, lw: Lagrange buffer indices and weights (equal length)
    amw:     corrector weights, ``len(amw) - 1 <= n``
    n:       eps history depth (buffers ``0..n``, newest last)
    k_max:   optional padded length (e.g. ``K_MAX``) for a fixed-shape
             AOT artifact; trailing slots get zero weight
    """
    if len(idx) != len(lw) or not amw or len(amw) - 1 > n:
        raise ValueError("malformed ERA combine coefficients")
    if any(j < 0 or j >= n for j in idx):
        raise ValueError(f"Lagrange index out of range (history {n})")
    out_len = n if k_max is None else k_max
    if out_len < n:
        raise ValueError(f"k_max {k_max} smaller than history {n}")
    w = [0.0] * out_len
    for j, lwj in zip(idx, lw):
        w[j] += amw[0] * lwj
    for m in range(len(amw) - 1):
        w[n - 1 - m] += amw[1 + m]
    return w
