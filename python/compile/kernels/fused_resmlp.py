"""Pallas kernel: fused FiLM-modulated residual MLP block.

This is the compute hot-spot of the denoiser (Layer 2 calls it once per
residual block per network evaluation, and network evaluations dominate
sampling cost — the premise of the whole fast-sampler literature).

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid over batch tiles; each step stages an (Bb, W) activation tile
    plus both (W, W) weight matrices in VMEM,
  * the two matmuls run back-to-back on the MXU with the SiLU fused
    between them on the VPU — the (Bb, W) intermediate never touches HBM,
  * W is chosen as a multiple of 128 (lane width) by the model config so
    the MXU tiles cleanly.

Runs under interpret=True here: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path and what the
AOT pipeline lowers into the exported HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Batch tile. 64 rows of f32[W] activations keeps three activation tiles
#: (h, scale, shift) + two W x W weight panels well inside the ~16 MiB of
#: VMEM for W <= 512 (see vmem_bytes below).
DEFAULT_BLOCK_B = 64


def _kernel(h_ref, scale_ref, shift_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One batch tile: out = h + silu((h*(1+scale)+shift) @ w1 + b1) @ w2 + b2."""
    h = h_ref[...]
    u = h * (1.0 + scale_ref[...]) + shift_ref[...]
    # First MXU matmul + fused VPU activation. Accumulate in f32 whatever
    # the storage dtype (preferred_element_type pins the MXU accumulator).
    mid = jnp.dot(u, w1_ref[...], preferred_element_type=jnp.float32)
    mid = mid + b1_ref[...][None, :]
    mid = mid * jax.nn.sigmoid(mid)  # SiLU, stays in VMEM
    out = jnp.dot(mid, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (h + out + b2_ref[...][None, :]).astype(o_ref.dtype)


def pick_block_b(batch: int, block_b: int = DEFAULT_BLOCK_B) -> int:
    """Largest tile <= block_b that divides `batch` (grids must tile exactly)."""
    bb = min(batch, block_b)
    while batch % bb != 0:
        bb -= 1
    return bb


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_resmlp(h, scale, shift, w1, b1, w2, b2, *, block_b: int = DEFAULT_BLOCK_B,
                 interpret: bool = True):
    """Fused residual block; same contract as kernels.ref.fused_resmlp_ref."""
    batch, width = h.shape
    bb = pick_block_b(batch, block_b)
    grid = (batch // bb,)

    act = pl.BlockSpec((bb, width), lambda i: (i, 0))
    full_mat = pl.BlockSpec((width, width), lambda i: (0, 0))
    full_vec = pl.BlockSpec((width,), lambda i: (0,))

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[act, act, act, full_mat, full_vec, full_mat, full_vec],
        out_specs=act,
        out_shape=jax.ShapeDtypeStruct((batch, width), h.dtype),
        interpret=interpret,
    )(h, scale, shift, w1, b1, w2, b2)


def vmem_bytes(block_b: int, width: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (for §Perf).

    Tiles resident per step: h/scale/shift/out activation tiles (4 x Bb x W)
    + intermediate (Bb x W) + both weight panels (2 x W x W) + biases.
    """
    act = 5 * block_b * width
    wgt = 2 * width * width + 2 * width
    return (act + wgt) * dtype_bytes


def mxu_flops(batch: int, width: int) -> int:
    """MACs*2 issued to the MXU per call (two W x W matmuls per row)."""
    return 2 * 2 * batch * width * width
