"""Layer 2: the JAX denoiser eps_theta(x, t).

A time-conditioned residual MLP — the stand-in for the paper's pretrained
DDPM UNets (see DESIGN.md §2). The architecture is deliberately the
smallest thing that exhibits the paper's premise (noise-estimation error
that grows as t -> 0) while keeping single-core CPU training to ~a minute
per dataset:

    x ──linear──▶ h ──[FiLM-ResBlock × n]──▶ linear ──▶ eps_hat
    t ──sinusoidal embed──mlp──▶ per-block (scale, shift)

Every residual block is the Layer-1 Pallas kernel
(`kernels.fused_resmlp`), so the exported HLO contains the kernel's
lowered body — Python is build-time only and never on the request path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels.fused_resmlp import fused_resmlp
from .kernels.ref import fused_resmlp_ref, time_embed_ref

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Denoiser hyperparameters; serialized into the artifact manifest."""

    dim: int
    width: int = 128
    n_blocks: int = 3
    temb_dim: int = 64
    temb_hidden: int = 128

    def to_json(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """He-initialised parameter pytree; zero-initialised output head."""
    ks = jax.random.split(key, 4 + 2 * cfg.n_blocks)

    def dense(k, n_in, n_out, scale=None):
        scale = scale if scale is not None else (2.0 / n_in) ** 0.5
        return {
            "w": scale * jax.random.normal(k, (n_in, n_out), jnp.float32),
            "b": jnp.zeros((n_out,), jnp.float32),
        }

    params: Params = {
        "in_proj": dense(ks[0], cfg.dim, cfg.width),
        "temb1": dense(ks[1], cfg.temb_dim, cfg.temb_hidden),
        "out": dense(ks[2], cfg.width, cfg.dim, scale=0.0),
        "blocks": [],
        "films": [],
    }
    for i in range(cfg.n_blocks):
        kb, kf = ks[3 + 2 * i], ks[4 + 2 * i]
        k1, k2 = jax.random.split(kb)
        params["blocks"].append(
            {
                # Second matmul down-scaled so each residual branch starts
                # near-identity; stabilises training of deeper stacks.
                "w1": (2.0 / cfg.width) ** 0.5
                * jax.random.normal(k1, (cfg.width, cfg.width), jnp.float32),
                "b1": jnp.zeros((cfg.width,), jnp.float32),
                "w2": 0.1
                * (2.0 / cfg.width) ** 0.5
                * jax.random.normal(k2, (cfg.width, cfg.width), jnp.float32),
                "b2": jnp.zeros((cfg.width,), jnp.float32),
            }
        )
        # FiLM head starts at zero: blocks begin time-independent.
        params["films"].append(dense(kf, cfg.temb_hidden, 2 * cfg.width, scale=0.0))
    return params


def eps_theta(params: Params, cfg: ModelConfig, x: jnp.ndarray, t: jnp.ndarray,
              *, use_pallas: bool = True) -> jnp.ndarray:
    """Predict the noise in x_t. x: (B, dim), t: (B,) in (0, 1]. -> (B, dim).

    `use_pallas=False` routes through the pure-jnp oracle instead of the
    Pallas kernel; pytest asserts both paths agree, and training uses the
    oracle path (faster under CPU interpret mode) while AOT export uses
    the kernel path so the artifact exercises Layer 1.
    """
    temb = time_embed_ref(t, cfg.temb_dim)
    temb = jax.nn.silu(temb @ params["temb1"]["w"] + params["temb1"]["b"])

    h = x @ params["in_proj"]["w"] + params["in_proj"]["b"]
    block_fn = fused_resmlp if use_pallas else fused_resmlp_ref
    for blk, film in zip(params["blocks"], params["films"]):
        film_out = temb @ film["w"] + film["b"]
        scale, shift = jnp.split(film_out, 2, axis=-1)
        h = block_fn(h, scale, shift, blk["w1"], blk["b1"], blk["w2"], blk["b2"])
    return h @ params["out"]["w"] + params["out"]["b"]


def param_count(params: Params) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(p.size) for p in leaves)
