"""VP diffusion process shared by training (Python) and sampling (Rust).

We use the continuous-time VP-SDE parameterisation of Song et al. 2020b,
which is what the DDIM / DPM-Solver line of work (and therefore the paper)
builds on. The closed form makes alpha_bar(t), logSNR(t) and its inverse
available analytically on both sides of the language boundary; the Rust
mirror lives in `rust/src/solvers/schedule.rs` and is tested against the
values exported in the artifact manifest.

    beta(t)      = beta_min + t * (beta_max - beta_min)
    alpha_bar(t) = exp(-0.5 * t^2 * (beta_max - beta_min) - t * beta_min)
    x_t          = sqrt(alpha_bar) * x_0 + sqrt(1 - alpha_bar) * eps
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

BETA_MIN = 0.1
BETA_MAX = 20.0


@dataclasses.dataclass(frozen=True)
class VpSchedule:
    """Continuous-time variance-preserving noise schedule."""

    beta_min: float = BETA_MIN
    beta_max: float = BETA_MAX

    def log_alpha_bar(self, t):
        return -0.25 * t**2 * (self.beta_max - self.beta_min) - 0.5 * t * self.beta_min

    def alpha_bar(self, t):
        """alpha_bar(t) = prod alpha_s in the discrete view; in (0, 1]."""
        return jnp.exp(2.0 * self.log_alpha_bar(t))

    def sqrt_alpha_bar(self, t):
        return jnp.exp(self.log_alpha_bar(t))

    def sigma(self, t):
        """sqrt(1 - alpha_bar(t)) — the noise scale at time t."""
        return jnp.sqrt(1.0 - self.alpha_bar(t))

    def log_snr(self, t):
        """logSNR(t) = log(alpha_bar / (1 - alpha_bar)).

        Monotone decreasing in t; used for the logSNR timestep grid that
        DPM-Solver (and the paper, on CIFAR-10) samples with.
        """
        ab = self.alpha_bar(t)
        return jnp.log(ab) - jnp.log1p(-ab)

    def q_sample(self, key: jax.Array, x0: jnp.ndarray, t: jnp.ndarray):
        """Forward diffusion: returns (x_t, eps) with eps ~ N(0, I)."""
        eps = jax.random.normal(key, x0.shape, dtype=x0.dtype)
        sab = self.sqrt_alpha_bar(t)[..., None]
        sig = self.sigma(t)[..., None]
        return sab * x0 + sig * eps, eps


def uniform_times(key: jax.Array, n: int, t_min: float = 1e-4, t_max: float = 1.0):
    """Training-time draw of diffusion times, uniform on [t_min, t_max]."""
    return jax.random.uniform(key, (n,), minval=t_min, maxval=t_max)
