"""Synthetic data manifolds standing in for the paper's image datasets.

The paper evaluates on CIFAR-10 (32x32), LSUN-Church (256x256),
LSUN-Bedroom (256x256) and CelebA (64x64) with pretrained DDPM UNets.
Neither the checkpoints nor the GPUs exist in this environment, so each
dataset is replaced by a synthetic manifold of matching *relative*
complexity (see DESIGN.md section 2). ERA-Solver itself is training-free
and dimension-agnostic: all it consumes is an imperfect eps_theta(x, t),
which a small denoiser trained on these manifolds provides.

Mapping (simple -> hard mirrors the paper's cross-dataset discussion):
  gmm8         -> CIFAR-10      (low-res, model trains well, low error)
  checkerboard -> LSUN-Church   (sharp discontinuous density)
  swissroll    -> LSUN-Bedroom  (curved filament manifold)
  rings        -> CelebA        (multi-scale radial structure)
  patches64    -> a 64-dim "image patch" manifold for a higher-dim run
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

DATASETS = ("gmm8", "checkerboard", "swissroll", "rings", "patches64")


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Static description of a synthetic dataset."""

    name: str
    dim: int
    #: paper dataset this manifold stands in for (documentation only)
    stands_in_for: str


SPECS = {
    "gmm8": DatasetSpec("gmm8", 2, "CIFAR-10"),
    "checkerboard": DatasetSpec("checkerboard", 2, "LSUN-Church"),
    "swissroll": DatasetSpec("swissroll", 2, "LSUN-Bedroom"),
    "rings": DatasetSpec("rings", 2, "CelebA"),
    "patches64": DatasetSpec("patches64", 64, "high-dim stress test"),
}

#: Fixed seed for the low-rank basis of `patches64`; the basis is exported
#: in the artifact manifest so the Rust side shares it exactly.
_PATCHES_BASIS_SEED = 7


def spec(name: str) -> DatasetSpec:
    if name not in SPECS:
        raise KeyError(f"unknown dataset {name!r}; available: {sorted(SPECS)}")
    return SPECS[name]


def patches_basis() -> np.ndarray:
    """(64, 8) smooth low-rank basis shared with the Rust data module."""
    rng = np.random.default_rng(_PATCHES_BASIS_SEED)
    # Smooth columns: random coefficients over low-frequency cosines of a
    # virtual 8x8 grid, mimicking correlated image patches.
    xs, ys = np.meshgrid(np.arange(8), np.arange(8), indexing="ij")
    cols = []
    for k in range(8):
        fx, fy = rng.integers(0, 3, size=2)
        phase = rng.uniform(0, 2 * np.pi)
        col = np.cos(np.pi * (fx * xs + fy * ys) / 8.0 + phase)
        cols.append(col.reshape(-1))
    basis = np.stack(cols, axis=1).astype(np.float32)
    basis /= np.linalg.norm(basis, axis=0, keepdims=True)
    return basis


def sample(name: str, key: jax.Array, n: int) -> jnp.ndarray:
    """Draw `n` samples from dataset `name`. Returns (n, dim) float32."""
    if name == "gmm8":
        return _sample_gmm8(key, n)
    if name == "checkerboard":
        return _sample_checkerboard(key, n)
    if name == "swissroll":
        return _sample_swissroll(key, n)
    if name == "rings":
        return _sample_rings(key, n)
    if name == "patches64":
        return _sample_patches64(key, n)
    raise KeyError(name)


def _sample_gmm8(key: jax.Array, n: int) -> jnp.ndarray:
    """8 Gaussians, std 0.15, equally spaced on a circle of radius 2."""
    k_mode, k_noise = jax.random.split(key)
    modes = jax.random.randint(k_mode, (n,), 0, 8)
    angles = 2.0 * jnp.pi * modes.astype(jnp.float32) / 8.0
    centers = 2.0 * jnp.stack([jnp.cos(angles), jnp.sin(angles)], axis=-1)
    return centers + 0.15 * jax.random.normal(k_noise, (n, 2))


def _sample_checkerboard(key: jax.Array, n: int) -> jnp.ndarray:
    """Uniform density on the black cells of a 4x4 checkerboard in [-2,2]^2."""
    k1, k2, k3 = jax.random.split(key, 3)
    # x uniform over [-2, 2); y uniform within a unit cell, then shifted to
    # the matching checker row.
    x = jax.random.uniform(k1, (n,), minval=-2.0, maxval=2.0)
    y_cell = jax.random.uniform(k2, (n,), minval=0.0, maxval=1.0)
    row = jax.random.randint(k3, (n,), 0, 2).astype(jnp.float32)
    col = jnp.floor(x + 2.0)  # 0..3
    # Black cells: (row + col) even -> offset rows by column parity.
    y = y_cell + 2.0 * row - 2.0 + jnp.mod(col, 2.0)
    return jnp.stack([x, y], axis=-1)


def _sample_swissroll(key: jax.Array, n: int) -> jnp.ndarray:
    """2-D swiss roll scaled into [-2, 2]^2, tangential noise 0.1."""
    k1, k2 = jax.random.split(key)
    t = jnp.sqrt(jax.random.uniform(k1, (n,), minval=0.0, maxval=1.0))
    theta = 3.0 * jnp.pi * t + 0.5 * jnp.pi
    r = 0.6 * t + 0.08
    pts = jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=-1)
    pts = pts * 2.4
    return pts + 0.05 * jax.random.normal(k2, (n, 2))


def _sample_rings(key: jax.Array, n: int) -> jnp.ndarray:
    """Two concentric rings (radii 0.8 and 1.8), radial noise 0.07."""
    k1, k2, k3 = jax.random.split(key, 3)
    which = jax.random.bernoulli(k1, 0.5, (n,)).astype(jnp.float32)
    radius = 0.8 + which * 1.0
    theta = jax.random.uniform(k2, (n,), minval=0.0, maxval=2.0 * jnp.pi)
    r = radius + 0.07 * jax.random.normal(k3, (n,))
    return jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=-1)


def _sample_patches64(key: jax.Array, n: int) -> jnp.ndarray:
    """64-dim correlated patches: tanh of a low-rank Gaussian field."""
    basis = jnp.asarray(patches_basis())  # (64, 8)
    z = jax.random.normal(key, (n, 8))
    return jnp.tanh(1.5 * (z @ basis.T)).astype(jnp.float32)


def reference_stats(name: str, n: int = 200_000, seed: int = 1234):
    """Mean and covariance of the data distribution, for Frechet distance.

    Exported into the artifact manifest; the Rust evaluation harness uses
    these as the "real data" side of FID so Python and Rust agree exactly.
    """
    key = jax.random.PRNGKey(seed)
    # Chunked to bound memory for the 64-dim dataset.
    chunks = []
    chunk = 50_000
    for i in range(0, n, chunk):
        key, sub = jax.random.split(key)
        chunks.append(np.asarray(sample(name, sub, min(chunk, n - i))))
    xs = np.concatenate(chunks, axis=0)
    mean = xs.mean(axis=0)
    cov = np.cov(xs, rowvar=False)
    cov = np.atleast_2d(cov)
    return mean.astype(np.float64), cov.astype(np.float64)
