"""Build-time training of the stand-in denoisers (one per dataset).

Runs once under `make artifacts`; the resulting parameters are baked into
the exported HLO as constants, so the Rust request path never sees Python.

Besides the weights, training also records the *noise-estimation error
curve* ||eps - eps_theta(x_t, t)|| as a function of t (paper Fig. 1): the
empirical fact that the error grows as t -> 0 is the premise of the
error-robust selection strategy, and EXPERIMENTS.md checks we actually
reproduce it.

No optax in this environment — Adam is hand-rolled below.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .diffusion import VpSchedule, uniform_times
from .model import ModelConfig, Params, eps_theta, init_params, param_count


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 6000
    batch: int = 512
    lr: float = 2e-3
    lr_final: float = 2e-4
    t_min: float = 1e-4
    seed: int = 0
    #: evaluation grid for the Fig.1 error curve
    err_bins: int = 32
    err_samples: int = 4096


def default_model_config(dataset: str) -> ModelConfig:
    d = datasets.spec(dataset).dim
    if d <= 2:
        return ModelConfig(dim=d, width=128, n_blocks=3)
    return ModelConfig(dim=d, width=256, n_blocks=3)


def default_train_config(dataset: str) -> TrainConfig:
    if datasets.spec(dataset).dim <= 2:
        return TrainConfig()
    return TrainConfig(steps=3000, batch=256)


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


def adam_init(params: Params):
    return {
        "m": jax.tree_util.tree_map(jnp.zeros_like, params),
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adam_update(state, grads, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), params, m, v
    )
    return {"m": m, "v": v, "step": step}, new_params


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------


def train(dataset: str, mcfg: ModelConfig | None = None, tcfg: TrainConfig | None = None,
          verbose: bool = True) -> Tuple[Params, ModelConfig, Dict[str, Any]]:
    """Train the denoiser for `dataset`; returns (params, cfg, report)."""
    mcfg = mcfg or default_model_config(dataset)
    tcfg = tcfg or default_train_config(dataset)
    sched = VpSchedule()
    key = jax.random.PRNGKey(tcfg.seed)
    key, k_init = jax.random.split(key)
    params = init_params(k_init, mcfg)

    def loss_fn(p, key):
        k_data, k_t, k_eps = jax.random.split(key, 3)
        x0 = datasets.sample(dataset, k_data, tcfg.batch)
        t = uniform_times(k_t, tcfg.batch, t_min=tcfg.t_min)
        x_t, eps = sched.q_sample(k_eps, x0, t)
        # Training uses the jnp oracle path: identical math to the Pallas
        # kernel (asserted in tests), much faster than interpret mode.
        eps_hat = eps_theta(p, mcfg, x_t, t, use_pallas=False)
        return jnp.mean((eps_hat - eps) ** 2)

    @jax.jit
    def step_fn(carry, key_lr):
        p, opt = carry
        key, lr = key_lr
        loss, grads = jax.value_and_grad(loss_fn)(p, key)
        opt, p = adam_update(opt, grads, p, lr)
        return (p, opt), loss

    opt = adam_init(params)
    losses = []
    t0 = time.time()
    # Cosine LR decay.
    lrs = tcfg.lr_final + 0.5 * (tcfg.lr - tcfg.lr_final) * (
        1 + np.cos(np.pi * np.arange(tcfg.steps) / tcfg.steps)
    )
    carry = (params, opt)
    for i in range(tcfg.steps):
        key, sub = jax.random.split(key)
        carry, loss = step_fn(carry, (sub, jnp.float32(lrs[i])))
        if i % 250 == 0 or i == tcfg.steps - 1:
            losses.append(float(loss))
            if verbose:
                print(f"[{dataset}] step {i:5d} loss {float(loss):.4f} "
                      f"({time.time() - t0:.0f}s)", flush=True)
    params, _ = carry

    key, k_err = jax.random.split(key)
    err_curve = noise_error_curve(params, mcfg, dataset, sched, k_err,
                                  bins=tcfg.err_bins, n=tcfg.err_samples)
    report = {
        "dataset": dataset,
        "loss_curve": losses,
        "final_loss": losses[-1],
        "param_count": param_count(params),
        "train_seconds": time.time() - t0,
        "error_curve": err_curve,
        "train_config": dataclasses.asdict(tcfg),
    }
    return params, mcfg, report


def noise_error_curve(params: Params, mcfg: ModelConfig, dataset: str,
                      sched: VpSchedule, key: jax.Array, bins: int = 32,
                      n: int = 4096) -> Dict[str, list]:
    """Paper Fig. 1: mean ||eps - eps_hat||_2 per time bin on fresh data."""
    ts = np.linspace(1.0 / bins, 1.0, bins).astype(np.float32)
    errs = []

    @jax.jit
    def bin_err(key, t_scalar):
        k_data, k_eps = jax.random.split(key)
        x0 = datasets.sample(dataset, k_data, n)
        t = jnp.full((n,), t_scalar)
        x_t, eps = sched.q_sample(k_eps, x0, t)
        eps_hat = eps_theta(params, mcfg, x_t, t, use_pallas=False)
        return jnp.mean(jnp.linalg.norm(eps_hat - eps, axis=-1))

    for t_scalar in ts:
        key, sub = jax.random.split(key)
        errs.append(float(bin_err(sub, jnp.float32(t_scalar))))
    return {"t": ts.tolist(), "err": errs}
