//! Regenerates the paper's FID-vs-NFE comparison tables (Tabs. 1/2/3/6).
//!
//! One dataset per invocation; the solver set and NFE axis follow the
//! paper exactly. The gmm8 dataset (the CIFAR-10 stand-in) follows the
//! paper's CIFAR-10 protocol: logSNR timestep grid, both t_N = 1e-3 and
//! 1e-4 variants for DPM-Solver-fast and ERA-Solver, and lambda = 0.9 (paper 15 rescaled).
//! The 256²-stand-ins (checkerboard/swissroll) use the LSUN protocol:
//! uniform grid, t_N = 1e-4, lambda = 0.3 (paper 5 rescaled).
//!
//! ```text
//! cargo run --release --example table_fid_sweep -- \
//!     --dataset checkerboard --out results/table1_church.md
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::experiments::report::{write_markdown_table, Table};
use era_solver::experiments::sweep::{run_sweep, Cell, EvalBackend, SweepConfig, SweepResult};
use era_solver::runtime::PjRtEngine;
use era_solver::solvers::schedule::GridKind;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: checkerboard)" },
    OptSpec { name: "out", value: Some("path"), help: "markdown output (default: results/table_<ds>.md)" },
    OptSpec { name: "samples", value: Some("n"), help: "samples per cell (default: 4096)" },
    OptSpec { name: "nfes", value: Some("a,b"), help: "NFE axis (default: paper's 5,10,12,15,20,40,50,100)" },
    OptSpec { name: "seed", value: Some("n"), help: "base seed (default: 0)" },
];

/// The paper's per-dataset protocol.
struct Protocol {
    grid: GridKind,
    /// (t_end, row-label suffix) variants; one for LSUN, two for CIFAR.
    t_ends: Vec<(f64, &'static str)>,
    era: &'static str,
    table_name: &'static str,
}

fn protocol(dataset: &str) -> Protocol {
    match dataset {
        // CIFAR-10 stand-in (Tab. 3): logSNR grid, both t_N, lambda=15.
        "gmm8" => Protocol {
            grid: GridKind::LogSnr,
            t_ends: vec![(1e-3, " (tN=1e-3)"), (1e-4, " (tN=1e-4)")],
            era: "era-4@0.9",
            table_name: "Tab. 3 (CIFAR-10 -> gmm8)",
        },
        // CelebA stand-in (Tab. 6).
        "rings" => Protocol {
            grid: GridKind::Quadratic,
            t_ends: vec![(1e-4, "")],
            era: "era-4@0.3",
            table_name: "Tab. 6 (CelebA -> rings)",
        },
        "swissroll" => Protocol {
            grid: GridKind::Uniform,
            t_ends: vec![(1e-4, "")],
            era: "era-3@0.3", // paper: k=3 on LSUN-Bedroom
            table_name: "Tab. 2 (LSUN-Bedroom -> swissroll)",
        },
        _ => Protocol {
            grid: GridKind::Uniform,
            t_ends: vec![(1e-4, "")],
            era: "era-4@0.3", // paper: k=4 on LSUN-Church
            table_name: "Tab. 1 (LSUN-Church -> checkerboard)",
        },
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse("table_fid_sweep: regenerate the paper's FID-vs-NFE tables", OPTS)?;
    let dataset = args.str_or("dataset", "checkerboard");
    let out = args.str_or("out", &format!("results/table_{dataset}.md"));
    let n_samples = args.usize_or("samples", 4096)?;
    let seed = args.u64_or("seed", 0)?;
    let nfes: Vec<usize> = args
        .list_or("nfes", &["5", "10", "12", "15", "20", "40", "50", "100"])
        .iter()
        .map(|s| s.parse().map_err(|_| format!("bad nfe '{s}'")))
        .collect::<Result<_, _>>()?;

    let engine = Arc::new(PjRtEngine::new(args.str_or("artifacts", "artifacts"))?);
    let backend = EvalBackend::pjrt(engine.clone(), &dataset)?;
    let proto = protocol(&dataset);

    // Baselines: the paper's comparison set (DDPM's 1000-step protocol
    // only appears in Tab. 3; we include it everywhere for completeness).
    let baselines = ["ddpm", "ddim", "fon", "pndm", "dpm-2", "dpm-fast"];

    let mut all_cells: Vec<Cell> = Vec::new();
    let mut row_order: Vec<String> = Vec::new();
    let mut run_one = |solvers: Vec<String>, t_end: f64, suffix: &str| {
        let cfg = SweepConfig {
            solvers,
            nfes: nfes.clone(),
            grid: proto.grid,
            t_end,
            n_samples,
            batch: 256,
            seed,
        };
        eprintln!("== {dataset} t_end={t_end} {suffix} ==");
        let res = run_sweep(&backend, &cfg);
        for mut cell in res.cells {
            let label = format!("{}{}", cell.solver, suffix);
            if !row_order.contains(&label) {
                row_order.push(label.clone());
            }
            cell.solver = label;
            all_cells.push(cell);
        }
    };

    if proto.t_ends.len() == 1 {
        // LSUN/CelebA layout: one t_N, every solver in one block.
        let mut solvers: Vec<String> = baselines.iter().map(|s| s.to_string()).collect();
        solvers.push(proto.era.to_string());
        run_one(solvers, proto.t_ends[0].0, "");
    } else {
        // CIFAR-10 layout (Tab. 3): baselines unsuffixed at the first
        // t_N; DPM-Solver-fast and ERA get one row per t_N variant.
        let base: Vec<String> =
            baselines.iter().filter(|s| **s != "dpm-fast").map(|s| s.to_string()).collect();
        run_one(base, proto.t_ends[0].0, "");
        for (t_end, suffix) in &proto.t_ends {
            run_one(vec!["dpm-fast".into(), proto.era.to_string()], *t_end, suffix);
        }
    }

    let sweep = SweepResult {
        cells: all_cells,
        config_label: format!(
            "dataset={dataset} grid={:?} n={n_samples} seed={seed} (paper protocol)",
            proto.grid
        ),
    };
    let table = Table::from_sweep(proto.table_name, &sweep, &row_order, &nfes);
    write_markdown_table(&out, &table).map_err(|e| e.to_string())?;
    eprintln!("wrote {out}");
    Ok(())
}
