//! Regenerates the ERS-vs-fixed selection ablation (Tabs. 4 and 5) and
//! the Fig. 4 qualitative comparison.
//!
//! For each Lagrange order k = 3..6 the sweep runs ERA-Solver with the
//! error-robust selection (ERS) and with the fixed last-k selection at
//! the paper's NFE axis. The paper's signature result — fixed selection
//! detonating at high order (k=6: FID 315 at NFE 20 on LSUN-Church)
//! while ERS stays stable — is the shape to look for.
//!
//! ```text
//! cargo run --release --example ablation_selection -- \
//!     --dataset checkerboard --out results/table4_ers_church.md --dump
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::experiments::report::{ascii_density, write_markdown_table, Table};
use era_solver::experiments::sweep::{generate, EvalBackend, SweepConfig, run_sweep};
use era_solver::runtime::PjRtEngine;
use era_solver::solvers::schedule::GridKind;
use era_solver::solvers::SolverKind;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: checkerboard)" },
    OptSpec { name: "out", value: Some("path"), help: "markdown output" },
    OptSpec { name: "samples", value: Some("n"), help: "samples per cell (default: 4096)" },
    OptSpec { name: "orders", value: Some("a,b"), help: "Lagrange orders (default: 3,4,5,6)" },
    OptSpec { name: "dump", value: None, help: "also dump Fig. 4 density plots (k=5)" },
    OptSpec { name: "lambda", value: Some("x"), help: "override ERS lambda (default: protocol)" },
    OptSpec { name: "nfes", value: Some("a,b"), help: "override NFE axis" },
    OptSpec { name: "seed", value: Some("n"), help: "base seed (default: 0)" },
];

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse("ablation_selection: ERS vs fixed selection (Tabs. 4/5, Fig. 4)", OPTS)?;
    let dataset = args.str_or("dataset", "checkerboard");
    let out = args.str_or("out", &format!("results/table_ers_{dataset}.md"));
    let n_samples = args.usize_or("samples", 4096)?;
    let seed = args.u64_or("seed", 0)?;
    let orders: Vec<usize> = args
        .list_or("orders", &["3", "4", "5", "6"])
        .iter()
        .map(|s| s.parse().map_err(|_| format!("bad order '{s}'")))
        .collect::<Result<_, _>>()?;

    // Paper protocol: lambda 5 / uniform on LSUN stand-ins, lambda 15 /
    // logSNR on the CIFAR stand-in; NFE axis matches Tab. 4 / Tab. 5.
    let (grid, proto_lambda, proto_nfes, title) = if dataset == "gmm8" {
        (GridKind::LogSnr, 0.9, vec![10usize, 15, 20, 50], "Tab. 5 (CIFAR-10 -> gmm8)")
    } else {
        (GridKind::Uniform, 0.3, vec![10usize, 15, 20, 40, 50], "Tab. 4 (LSUN-Church -> checkerboard)")
    };
    let lambda = args.f64_or("lambda", proto_lambda)?;
    let nfes: Vec<usize> = match args.present("nfes") {
        false => proto_nfes,
        true => args
            .list_or("nfes", &[])
            .iter()
            .map(|s| s.parse().map_err(|_| format!("bad nfe '{s}'")))
            .collect::<Result<_, _>>()?,
    };

    let engine = Arc::new(PjRtEngine::new(args.str_or("artifacts", "artifacts"))?);
    let backend = EvalBackend::pjrt(engine, &dataset)?;

    let mut solvers = Vec::new();
    let mut row_order = Vec::new();
    for &k in &orders {
        solvers.push(format!("era-fixed-{k}"));
        solvers.push(format!("era-{k}@{lambda}"));
        row_order.push(format!("ERA-Solver-{k} fixed"));
        row_order.push(format!("ERA-Solver-{k} ERS"));
    }
    let cfg = SweepConfig {
        solvers: solvers.clone(),
        nfes: nfes.clone(),
        grid,
        t_end: if dataset == "gmm8" { 1e-3 } else { 1e-4 },
        n_samples,
        batch: 256,
        seed,
    };
    let mut res = run_sweep(&backend, &cfg);
    // Rename rows to the paper's labels.
    for cell in &mut res.cells {
        cell.solver = if let Some(k) = cell.solver.strip_prefix("era-fixed-") {
            format!("ERA-Solver-{k} fixed")
        } else if let Some(rest) = cell.solver.strip_prefix("era-") {
            let k = rest.split('@').next().unwrap();
            format!("ERA-Solver-{k} ERS")
        } else {
            cell.solver.clone()
        };
    }
    let table = Table::from_sweep(title, &res, &row_order, &nfes);
    write_markdown_table(&out, &table).map_err(|e| e.to_string())?;
    eprintln!("wrote {out}");

    if args.present("dump") && backend.dim() == 2 {
        // Fig. 4: qualitative ERS-vs-fixed at k=5.
        let nfe = 20;
        for (name, solver) in [
            ("fig4_fixed5", format!("era-fixed-5")),
            ("fig4_ers5", format!("era-5@{lambda}")),
        ] {
            let kind = SolverKind::parse(&solver).unwrap();
            let (samples, _) =
                generate(&backend, &kind, nfe, grid, cfg.t_end, 2048, 256, seed);
            let art = ascii_density(&samples, 33, 3.2);
            let path = format!("results/{name}_{dataset}.txt");
            std::fs::create_dir_all("results").map_err(|e| e.to_string())?;
            std::fs::write(&path, &art).map_err(|e| e.to_string())?;
            println!("\n{solver} @ {nfe} NFE ({dataset}):\n{art}");
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}
