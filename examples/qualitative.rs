//! Qualitative sample comparison (Figs. 8–12 stand-in): density dumps of
//! generated samples per solver and NFE, as ASCII plots + CSV point
//! clouds. The paper's visual claim — ERA output is already on-manifold
//! at NFE 10–15 where baselines still drift — shows up directly in the
//! densities.
//!
//! ```text
//! cargo run --release --example qualitative -- --dataset checkerboard
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::experiments::report::{ascii_density, write_csv};
use era_solver::experiments::sweep::{generate, EvalBackend};
use era_solver::metrics;
use era_solver::runtime::PjRtEngine;
use era_solver::solvers::schedule::GridKind;
use era_solver::solvers::SolverKind;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: checkerboard)" },
    OptSpec { name: "out-dir", value: Some("dir"), help: "output dir (default: results/qualitative)" },
    OptSpec { name: "samples", value: Some("n"), help: "samples per plot (default: 2048)" },
    OptSpec { name: "solvers", value: Some("a,b"), help: "solvers (default: ddim,dpm-fast,era-4@0.3)" },
    OptSpec { name: "nfes", value: Some("a,b"), help: "NFE axis (default: 5,8,10,12,15,20)" },
];

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse("qualitative: per-solver sample densities (Figs. 8-12)", OPTS)?;
    let dataset = args.str_or("dataset", "checkerboard");
    let out_dir = args.str_or("out-dir", "results/qualitative");
    let n = args.usize_or("samples", 2048)?;
    let solvers = args.list_or("solvers", &["ddim", "dpm-fast", "era-4@0.3"]);
    let nfes: Vec<usize> = args
        .list_or("nfes", &["5", "8", "10", "12", "15", "20"])
        .iter()
        .map(|s| s.parse().map_err(|_| format!("bad nfe '{s}'")))
        .collect::<Result<_, _>>()?;
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;

    let engine = Arc::new(PjRtEngine::new(args.str_or("artifacts", "artifacts"))?);
    let backend = EvalBackend::pjrt(engine, &dataset)?;
    let reference = backend.reference();
    let grid = if dataset == "gmm8" { GridKind::LogSnr } else { GridKind::Uniform };

    for solver in &solvers {
        let kind = SolverKind::parse(solver).ok_or(format!("unknown solver '{solver}'"))?;
        for &nfe in &nfes {
            if nfe < kind.min_nfe() {
                println!("-- {solver} @ {nfe} NFE: below minimum budget, skipped");
                continue;
            }
            let (samples, _) = generate(&backend, &kind, nfe, grid, 1e-4, n, 256, 3);
            let fid = metrics::fid(&samples, &reference);
            let stem = format!("{out_dir}/{dataset}_{}_nfe{nfe}", solver.replace('@', "_"));
            if samples.cols() == 2 {
                let art = ascii_density(&samples, 33, 3.2);
                std::fs::write(format!("{stem}.txt"), &art).map_err(|e| e.to_string())?;
                println!("-- {solver} @ {nfe} NFE (FID {fid:.3}):\n{art}");
            } else {
                println!("-- {solver} @ {nfe} NFE (FID {fid:.3}, dim {})", samples.cols());
            }
            // Point cloud (first 512 rows) for external plotting.
            let keep = samples.rows().min(512);
            let cols: Vec<Vec<f64>> = (0..samples.cols().min(2))
                .map(|c| (0..keep).map(|r| samples.row(r)[c] as f64).collect())
                .collect();
            let header: Vec<&str> = ["x", "y"][..cols.len()].to_vec();
            write_csv(&format!("{stem}.csv"), &header, &cols).map_err(|e| e.to_string())?;
        }
    }
    eprintln!("wrote plots under {out_dir}/");
    Ok(())
}
