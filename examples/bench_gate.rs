//! CI regression gate over the persistent perf trajectory: compare the
//! `BENCH_*.json` reports a fresh bench run emitted (via
//! `$ERA_BENCH_JSON_DIR`) against the baselines committed under
//! `benchmarks/`, and fail loudly — naming the regressed metric — when
//! a fresh value leaves its baseline's tolerance band.
//!
//! ```text
//! ERA_BENCH_JSON_DIR=/tmp/bench cargo bench ...   # emit fresh reports
//! cargo run --release --example bench_gate -- benchmarks /tmp/bench
//! ```
//!
//! Every baseline file must have a fresh counterpart; a bench suite
//! that silently stopped emitting is itself a regression. Fresh metrics
//! absent from the baseline are informational only (new metrics land in
//! the trajectory first, get promoted to gates by committing them).

use std::path::Path;

use era_solver::obs::BenchReport;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_gate <baseline-dir> <fresh-dir>");
        std::process::exit(2);
    }
    let baseline_dir = Path::new(&args[1]);
    let fresh_dir = Path::new(&args[2]);

    let mut baselines: Vec<std::path::PathBuf> = std::fs::read_dir(baseline_dir)
        .unwrap_or_else(|e| {
            eprintln!("bench_gate: cannot read {}: {e}", baseline_dir.display());
            std::process::exit(2);
        })
        .filter_map(|entry| {
            let path = entry.ok()?.path();
            let name = path.file_name()?.to_str()?;
            (name.starts_with("BENCH_") && name.ends_with(".json")).then_some(path)
        })
        .collect();
    baselines.sort();
    if baselines.is_empty() {
        eprintln!("bench_gate: no BENCH_*.json baselines in {}", baseline_dir.display());
        std::process::exit(2);
    }

    let mut failures: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for base_path in &baselines {
        let name = base_path.file_name().unwrap().to_str().unwrap();
        let baseline = match BenchReport::load(base_path) {
            Ok(r) => r,
            Err(e) => {
                failures.push(format!("{name}: unreadable baseline: {e}"));
                continue;
            }
        };
        let fresh_path = fresh_dir.join(name);
        let fresh = match BenchReport::load(&fresh_path) {
            Ok(r) => r,
            Err(e) => {
                failures.push(format!(
                    "{name}: fresh report missing — did the bench stop emitting? ({e})"
                ));
                continue;
            }
        };
        let regs = fresh.regressions_against(&baseline);
        checked += baseline.metrics.len();
        for r in &regs {
            failures.push(r.clone());
        }
        for m in &baseline.metrics {
            if let Some(cur) = fresh.get(&m.name) {
                println!(
                    "bench_gate: {}/{}: baseline {} -> fresh {} ({}, tol {})",
                    baseline.suite,
                    m.name,
                    m.value,
                    cur.value,
                    if regs.iter().any(|r| r.contains(&m.name)) { "REGRESSED" } else { "ok" },
                    m.tolerance,
                );
            }
        }
    }

    if failures.is_empty() {
        println!(
            "bench_gate: {} metric(s) across {} suite(s) within tolerance",
            checked,
            baselines.len()
        );
    } else {
        eprintln!("bench_gate: {} regression(s):", failures.len());
        for f in &failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
}
