//! Regenerates the error-measure ablation (Figs. 5 and 6): the
//! error-aware power scale (delta_eps / lambda, Eq. 17) versus constant
//! scales.
//!
//! The paper's point: no single constant exponent matches the adaptive
//! one across NFE — the measured error feeds information the constant
//! cannot have. Output is a CSV (one series per scale) plus a markdown
//! summary.
//!
//! ```text
//! cargo run --release --example ablation_scale -- \
//!     --dataset checkerboard --out results/fig5_scale_church.md
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::experiments::report::{write_csv, write_markdown_table, Table};
use era_solver::experiments::sweep::{run_sweep, EvalBackend, SweepConfig};
use era_solver::runtime::PjRtEngine;
use era_solver::solvers::schedule::GridKind;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: checkerboard)" },
    OptSpec { name: "out", value: Some("path"), help: "markdown output" },
    OptSpec { name: "samples", value: Some("n"), help: "samples per cell (default: 4096)" },
    OptSpec { name: "scales", value: Some("a,b"), help: "constant scales (default: 0.25,0.5,1,2,4)" },
    OptSpec { name: "seed", value: Some("n"), help: "base seed (default: 0)" },
];

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse("ablation_scale: error-aware vs constant scale (Figs. 5/6)", OPTS)?;
    let dataset = args.str_or("dataset", "checkerboard");
    let out = args.str_or("out", &format!("results/fig_scale_{dataset}.md"));
    let n_samples = args.usize_or("samples", 4096)?;
    let seed = args.u64_or("seed", 0)?;
    let scales: Vec<f64> = args
        .list_or("scales", &["0.25", "0.5", "1", "2", "4"])
        .iter()
        .map(|s| s.parse().map_err(|_| format!("bad scale '{s}'")))
        .collect::<Result<_, _>>()?;

    // Paper protocol: Fig. 5 uses k=3 on LSUN-Church; Fig. 6 uses k=4 on
    // CIFAR-10.
    let (k, grid, lambda, t_end, title) = if dataset == "gmm8" {
        (4, GridKind::LogSnr, 0.9, 1e-3, "Fig. 6 (CIFAR-10 -> gmm8, k=4)")
    } else {
        (3, GridKind::Uniform, 0.3, 1e-4, "Fig. 5 (LSUN-Church -> checkerboard, k=3)")
    };
    let nfes = vec![10usize, 15, 20, 40, 50];

    let engine = Arc::new(PjRtEngine::new(args.str_or("artifacts", "artifacts"))?);
    let backend = EvalBackend::pjrt(engine, &dataset)?;

    let mut solvers = vec![format!("era-{k}@{lambda}")];
    let mut row_order = vec!["error-aware (Eq. 17)".to_string()];
    for s in &scales {
        solvers.push(format!("era-const-{k}@{s}"));
        row_order.push(format!("constant scale {s}"));
    }
    let cfg = SweepConfig {
        solvers,
        nfes: nfes.clone(),
        grid,
        t_end,
        n_samples,
        batch: 256,
        seed,
    };
    let mut res = run_sweep(&backend, &cfg);
    for cell in &mut res.cells {
        cell.solver = if cell.solver.starts_with("era-const-") {
            let scale = cell.solver.split('@').nth(1).unwrap();
            format!("constant scale {scale}")
        } else {
            "error-aware (Eq. 17)".to_string()
        };
    }
    let table = Table::from_sweep(title, &res, &row_order, &nfes);
    write_markdown_table(&out, &table).map_err(|e| e.to_string())?;

    // CSV series for the figure.
    let mut header: Vec<&str> = vec!["nfe"];
    let mut columns: Vec<Vec<f64>> = vec![nfes.iter().map(|&n| n as f64).collect()];
    let owned_labels = row_order.clone();
    for label in &owned_labels {
        header.push(label);
        columns.push(
            nfes.iter()
                .map(|&n| res.fid(label, n).unwrap_or(f64::NAN))
                .collect(),
        );
    }
    let csv_path = out.replace(".md", ".csv");
    write_csv(&csv_path, &header, &columns).map_err(|e| e.to_string())?;
    eprintln!("wrote {out} and {csv_path}");
    Ok(())
}
