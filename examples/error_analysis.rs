//! Regenerates the paper's error-analysis figures:
//!
//! * **Fig. 1** — training-time noise-estimation error vs t (from the
//!   train reports written at `make artifacts` time), demonstrating the
//!   premise that the error grows as t -> 0.
//! * **Fig. 3** — sampling-time error measure delta_eps (Eq. 15) per
//!   step plus the ERS-selected buffer indices, showing the selection
//!   leaning toward early (accurate) estimates as the error rises.
//! * **Fig. 7** — round-trip error (Eq. 18): diffuse generated samples
//!   back to time t and measure ||eps - eps_theta(x_t^gen, t)|| per
//!   solver; an error-robust solver stays closer to the model's own
//!   denoising field.
//!
//! ```text
//! cargo run --release --example error_analysis -- --out-dir results
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::experiments::report::write_csv;
use era_solver::rng::Rng;
use era_solver::runtime::{PjRtEngine, PjRtEps, TrainReport};
use era_solver::solvers::era::{EraSolver, Selection};
use era_solver::solvers::schedule::{make_grid, GridKind};
use era_solver::solvers::{sample_with, SolverKind};
use era_solver::tensor::Tensor;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: checkerboard)" },
    OptSpec { name: "out-dir", value: Some("dir"), help: "output directory (default: results)" },
    OptSpec { name: "nfe", value: Some("n"), help: "NFE for Figs. 3/7 (default: 20)" },
    OptSpec { name: "samples", value: Some("n"), help: "batch for Figs. 3/7 (default: 512)" },
    OptSpec { name: "fig1", value: None, help: "only Fig. 1" },
    OptSpec { name: "fig3", value: None, help: "only Fig. 3" },
    OptSpec { name: "fig7", value: None, help: "only Fig. 7" },
];

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse("error_analysis: Figs. 1/3/7", OPTS)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    let dataset = args.str_or("dataset", "checkerboard");
    let out_dir = args.str_or("out-dir", "results");
    let nfe = args.usize_or("nfe", 20)?;
    let n = args.usize_or("samples", 512)?;
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;
    let all = !(args.present("fig1") || args.present("fig3") || args.present("fig7"));

    let engine = Arc::new(PjRtEngine::new(&artifacts)?);
    let sched = engine.manifest().schedule;
    let dim = engine.dataset(&dataset)?.dim;

    // ---- Fig. 1: training-time error curve -------------------------------
    if all || args.present("fig1") {
        let datasets: Vec<String> = engine.manifest().datasets.keys().cloned().collect();
        let mut header: Vec<String> = vec!["t".into()];
        let mut columns: Vec<Vec<f64>> = Vec::new();
        for (i, ds) in datasets.iter().enumerate() {
            let rep = TrainReport::load(&artifacts, ds)?;
            if i == 0 {
                columns.push(rep.error_curve.iter().map(|p| p.0).collect());
            }
            header.push(ds.clone());
            columns.push(rep.error_curve.iter().map(|p| p.1).collect());
        }
        let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let path = format!("{out_dir}/fig1_train_error.csv");
        write_csv(&path, &href, &columns).map_err(|e| e.to_string())?;
        // Print the trend check the paper's premise rests on.
        for ds in &datasets {
            let rep = TrainReport::load(&artifacts, ds)?;
            let first = rep.error_curve.first().unwrap();
            let last = rep.error_curve.last().unwrap();
            println!(
                "fig1 {ds}: err(t={:.3})={:.4} vs err(t={:.3})={:.4} (grows toward 0: {})",
                first.0,
                first.1,
                last.0,
                last.1,
                first.1 > last.1
            );
        }
        eprintln!("wrote {path}");
    }

    // ---- Fig. 3: sampling-time delta_eps + selected indices --------------
    if all || args.present("fig3") {
        let grid_kind = if dataset == "gmm8" { GridKind::LogSnr } else { GridKind::Uniform };
        let grid = make_grid(&sched, grid_kind, nfe, 1.0, 1e-4);
        let mut rng = Rng::new(0);
        let mut solver = EraSolver::new(
            sched,
            grid,
            rng.normal_tensor(n, dim),
            4,
            Selection::ErrorRobust { lambda: 0.3 },
        );
        let model = PjRtEps::new(&engine, &dataset)?;
        let _ = sample_with(&mut solver, &model);

        // selection_trace() materialises the flat per-step log; bind it
        // once for the four plot columns.
        let trace = solver.selection_trace();
        let steps: Vec<f64> = trace.iter().map(|t| t.step as f64).collect();
        let errs: Vec<f64> = trace.iter().map(|t| t.delta_eps).collect();
        let min_idx: Vec<f64> = trace.iter().map(|t| t.indices[0] as f64).collect();
        let span: Vec<f64> = trace
            .iter()
            .map(|t| (t.indices[t.indices.len() - 1] - t.indices[0]) as f64)
            .collect();
        let path = format!("{out_dir}/fig3_delta_eps_{dataset}.csv");
        write_csv(
            &path,
            &["step", "delta_eps", "earliest_selected", "selection_span"],
            &[steps, errs.clone(), min_idx, span],
        )
        .map_err(|e| e.to_string())?;
        println!(
            "fig3 {dataset}: delta_eps first={:.4} last={:.4} (sampling-time error rises: {})",
            errs.first().unwrap(),
            errs.last().unwrap(),
            errs.last() > errs.first()
        );
        eprintln!("wrote {path}");
    }

    // ---- Fig. 7: round-trip error per solver ------------------------------
    if all || args.present("fig7") {
        let model = PjRtEps::new(&engine, &dataset)?;
        let grid_kind = if dataset == "gmm8" { GridKind::LogSnr } else { GridKind::Uniform };
        let solvers = ["iadams", "dpm-fast", "era-4@0.3"];
        let ts: Vec<f64> = (1..=16).map(|i| i as f64 / 16.0).collect();
        let mut columns: Vec<Vec<f64>> = vec![ts.clone()];
        let mut header: Vec<String> = vec!["t".into()];

        for sname in solvers {
            let kind = SolverKind::parse(sname).unwrap();
            let steps = kind.steps_for_nfe(nfe);
            let grid = make_grid(&sched, grid_kind, steps, 1.0, 1e-4);
            let mut rng = Rng::new(1);
            let x0 = rng.normal_tensor(n, dim);
            let mut solver = kind.build(sched, grid, x0, 1, nfe);
            let gen = sample_with(&mut *solver, &model);

            // Diffuse the generated batch back to each probe time with a
            // *shared* noise draw (same seed across solvers) and measure
            // Eq. 18 through the trained network.
            let mut series = Vec::with_capacity(ts.len());
            for &t in &ts {
                let mut noise_rng = Rng::for_stream(99, (t * 1e6) as u64);
                let eps_true = noise_rng.normal_tensor(n, dim);
                let sab = sched.sqrt_alpha_bar(t) as f32;
                let sig = sched.sigma(t) as f32;
                let mut xt = gen.clone();
                xt.scale(sab);
                xt.axpy(sig, &eps_true);
                let eps_hat = engine.eval_eps(&dataset, &xt, &vec![t as f32; n])?;
                series.push(eps_hat.mean_row_dist(&eps_true) as f64);
            }
            let mean: f64 = series.iter().sum::<f64>() / series.len() as f64;
            println!("fig7 {dataset} {sname}: mean round-trip error {mean:.4}");
            header.push(sname.to_string());
            columns.push(series);
        }
        let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let path = format!("{out_dir}/fig7_roundtrip_{dataset}.csv");
        write_csv(&path, &href, &columns).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
