//! Perf probe: per-call cost of the PJRT hot-path primitives, used by
//! the §Perf iteration log in EXPERIMENTS.md (quick, targeted numbers;
//! the full suites live in `benches/`).
//!
//! ```text
//! cargo run --release --example perf_probe
//! ```

use std::sync::Arc;

use era_solver::metrics::{self, Moments};
use era_solver::rng::Rng;
use era_solver::runtime::PjRtEngine;
use era_solver::tensor::Tensor;

fn main() {
    let eng = Arc::new(PjRtEngine::new("artifacts").expect("run `make artifacts` first"));
    eng.warmup("gmm8", &[256]).unwrap();
    let mut rng = Rng::new(0);
    let x = rng.normal_tensor(256, 2);
    let t = vec![0.5f32; 256];
    let n = 200u32;

    // Denoiser artifact (the L2 graph incl. the L1 Pallas block).
    for _ in 0..5 {
        eng.eval_eps("gmm8", &x, &t).unwrap();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        eng.eval_eps("gmm8", &x, &t).unwrap();
    }
    let per = t0.elapsed() / n;
    // 3 res-blocks x 2 matmuls (128x128) x 256 rows ~ 50.3 MFLOP/eval.
    let gflops = 50.33e6 / per.as_secs_f64() / 1e9;
    println!("eval_eps 256x2 (W=128, 3 blocks): {per:?}/call  (~{gflops:.1} GFLOP/s)");

    // Fused solver-update artifact vs its native Rust twin.
    let e: Vec<Tensor> = (0..4).map(|_| rng.normal_tensor(256, 2)).collect();
    let er: Vec<&Tensor> = e.iter().collect();
    for _ in 0..5 {
        eng.combine("gmm8", &er, &[0.25; 4], &x, (0.9, 0.1)).unwrap();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        eng.combine("gmm8", &er, &[0.25; 4], &x, (0.9, 0.1)).unwrap();
    }
    println!("combine artifact 256x2 k=4: {:?}/call", t0.elapsed() / n);
    let w32 = [0.25f32; 4];
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        std::hint::black_box(Tensor::kernel_weighted_sum(&x, 0.9, 0.1, &er, &w32));
    }
    println!("native twin   256x2 k=4: {:?}/call", t0.elapsed() / n);

    // FID at the high-dim stress point (sqrtm-bound).
    let hi = rng.normal_tensor(2048, 64);
    let rf = Moments::from_tensor(&rng.normal_tensor(2048, 64));
    for _ in 0..3 {
        metrics::fid(&hi, &rf);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..50 {
        std::hint::black_box(metrics::fid(&hi, &rf));
    }
    println!("fid 2048x64: {:?}/call", t0.elapsed() / 50);
}
