//! Quickstart: the full stack in one file.
//!
//! 1. Load the AOT artifacts through PJRT (`make artifacts` first).
//! 2. Start the continuous-batching coordinator and the TCP server.
//! 3. Sample a batch with ERA-Solver at 10 NFE through a real network
//!    connection, print FID against the manifest's reference moments and
//!    an ASCII density of the generated 2-D samples.
//!
//! ```text
//! cargo run --release --example quickstart -- --dataset gmm8 --nfe 10
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::coordinator::{ModelBank, RequestSpec};
use era_solver::experiments::report::ascii_density;
use era_solver::metrics;
use era_solver::pool::{PoolConfig, WorkerPool};
use era_solver::runtime::PjRtEngine;
use era_solver::server::{client::Client, Server, ServerConfig};

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: gmm8)" },
    OptSpec { name: "solver", value: Some("name"), help: "solver (default: era)" },
    OptSpec { name: "nfe", value: Some("n"), help: "evaluation budget (default: 10)" },
    OptSpec { name: "samples", value: Some("n"), help: "samples to generate (default: 2048)" },
];

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse("quickstart: sample through the full serving stack", OPTS)?;
    let dataset = args.str_or("dataset", "gmm8");
    let solver = args.str_or("solver", "era");
    let nfe = args.usize_or("nfe", 10)?;
    let n_samples = args.usize_or("samples", 2048)?;

    // --- Layer 3 bring-up -------------------------------------------------
    let engine = Arc::new(PjRtEngine::new(args.str_or("artifacts", "artifacts"))?);
    engine.warmup(&dataset, &engine.manifest().batch_buckets.clone())?;
    let entry = engine.dataset(&dataset)?.clone();
    println!(
        "loaded '{dataset}' (stands in for {}; dim {}, final train loss {:.4})",
        entry.stands_in_for, entry.dim, entry.final_loss
    );

    let bank: Arc<dyn ModelBank> = engine;
    let pool = Arc::new(WorkerPool::start(bank, PoolConfig::default()));
    let server = Server::start(pool.clone(), ServerConfig::default())
        .map_err(|e| e.to_string())?;
    let addr = server.local_addr();
    println!("serving on {addr}");

    // --- A real client request --------------------------------------------
    let mut client = Client::connect(addr).map_err(|e| e.to_string())?;
    client.ping()?;
    let spec = RequestSpec {
        dataset: dataset.clone(),
        solver: solver.clone(),
        nfe,
        n_samples,
        grid: if dataset == "gmm8" { "logsnr".into() } else { "uniform".into() },
        t_end: 1e-3,
        seed: 7,
        deadline_ms: None,
        task: Default::default(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (samples, server_seconds) = client.sample(&spec)?;
    let wall = t0.elapsed().as_secs_f64();

    let fid = metrics::fid(&samples, &entry.ref_stats);
    println!(
        "\n{} samples via {solver}@{nfe} NFE in {:.3}s wall ({:.3}s server): FID {:.4}",
        samples.rows(),
        wall,
        server_seconds,
        fid
    );
    if samples.cols() == 2 {
        println!("\nsample density:\n{}", ascii_density(&samples, 33, 3.2));
    }
    let stats = client.stats()?;
    println!("server stats: {}", stats.to_string());

    server.shutdown();
    Ok(())
}
