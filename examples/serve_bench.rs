//! End-to-end serving benchmark (Tab. 7 reproduction): wall-clock per
//! sampling run vs NFE per solver, measured through the full
//! client -> TCP -> coordinator -> PJRT path, plus throughput/latency
//! under concurrent load and a batching-policy ablation.
//!
//! This is the repository's end-to-end driver: it loads real trained
//! artifacts, serves batched concurrent requests, and reports the
//! latency/throughput numbers recorded in EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example serve_bench -- --out results/table7_serving.md
//! ```

use std::sync::Arc;

use era_solver::cli::{Args, OptSpec};
use era_solver::coordinator::{BatchPolicy, CoordinatorConfig, ModelBank, QosClass, RequestSpec};
use era_solver::experiments::report::{write_markdown_table, Table};
use era_solver::pool::{PlacementPolicy, PoolConfig, WorkerPool};
use era_solver::runtime::PjRtEngine;
use era_solver::server::client::{generate_load, generate_load_with, Client, LoadOptions};
use era_solver::server::protocol::Encoding;
use era_solver::server::{Server, ServerConfig};
use era_solver::solvers::TaskSpec;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact tree (default: artifacts)" },
    OptSpec { name: "dataset", value: Some("name"), help: "dataset (default: checkerboard)" },
    OptSpec { name: "out", value: Some("path"), help: "markdown output (default: results/table7_serving.md)" },
    OptSpec { name: "batch", value: Some("n"), help: "samples per request (default: 64)" },
    OptSpec { name: "concurrency", value: Some("n"), help: "load-gen workers (default: 8)" },
    OptSpec { name: "requests", value: Some("n"), help: "requests per worker (default: 6)" },
    OptSpec { name: "connections", value: Some("n"), help: "load-gen connections, one per worker (default: = concurrency)" },
    OptSpec { name: "reuse", value: Some("0|1"), help: "1: each worker keeps one connection across its requests; 0: reconnect per request (default: 1)" },
    OptSpec { name: "encoding", value: Some("json|bin"), help: "sample-delivery wire encoding: json = decimal-text rows, bin = JSON header + counted little-endian f32 payload (default: json)" },
    OptSpec { name: "shards", value: Some("n"), help: "pool shards (default: 1)" },
    OptSpec { name: "executors", value: Some("n"), help: "engine executors per shard (default: 1)" },
    OptSpec { name: "pipeline-depth", value: Some("n"), help: "dispatch rounds in flight per shard (default: 2)" },
    OptSpec { name: "guidance", value: Some("s"), help: "CFG scale for the load phase, 0 = off (default: 0)" },
    OptSpec { name: "guide-class", value: Some("c"), help: "class id for guided rows (default: 0)" },
    OptSpec { name: "churn", value: Some("s"), help: "stochastic-ERA churn for the load phase (default: 0)" },
    OptSpec { name: "qos", value: Some("class"), help: "QoS class for the load phase: strict | balanced | besteffort (default: strict)" },
    OptSpec { name: "min-nfe", value: Some("n"), help: "early-stop NFE floor for the load phase, 0 = solver minimum (default: 0)" },
    OptSpec { name: "conv-threshold", value: Some("x"), help: "convergence threshold for the load phase, 0 = fixed NFE (default: 0)" },
    OptSpec { name: "emit-bench-json", value: Some("path"), help: "write the load phase's BENCH_serving.json report here" },
];

fn main() {
    if let Err(e) = run() {
        eprintln!("{e}");
        std::process::exit(2);
    }
}

struct Stack {
    server: Server,
    pool: Arc<WorkerPool>,
}

fn start_stack(
    artifacts: &str,
    dataset: &str,
    policy: BatchPolicy,
    shards: usize,
    executors: usize,
    pipeline_depth: usize,
) -> Result<Stack, String> {
    let engine = Arc::new(PjRtEngine::new(artifacts)?);
    engine.warmup(dataset, &engine.manifest().batch_buckets.clone())?;
    let bank: Arc<dyn ModelBank> = engine;
    let pool = Arc::new(WorkerPool::start(
        bank,
        PoolConfig {
            shards,
            placement: PlacementPolicy::LeastLoaded,
            shard: CoordinatorConfig {
                max_active: 64,
                queue_capacity: 512,
                policy,
                executors_per_shard: executors,
                pipeline_depth,
                ..Default::default()
            },
            max_inflight_rows: 0,
        },
    ));
    let server = Server::start(pool.clone(), ServerConfig::default())
        .map_err(|e| e.to_string())?;
    Ok(Stack { server, pool })
}

fn run() -> Result<(), String> {
    let args = Args::parse("serve_bench: Tab. 7 serving reproduction", OPTS)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    let dataset = args.str_or("dataset", "checkerboard");
    let out = args.str_or("out", "results/table7_serving.md");
    let batch = args.usize_or("batch", 64)?;
    let concurrency = args.usize_or("concurrency", 8)?;
    let requests = args.usize_or("requests", 6)?;
    let connections = args.usize_or("connections", concurrency)?.max(1);
    let reuse = args.usize_or("reuse", 1)? != 0;
    let enc_name = args.str_or("encoding", "json");
    let encoding = Encoding::parse(&enc_name)
        .ok_or_else(|| format!("unknown encoding '{enc_name}' (expected json or bin)"))?;
    let shards = args.usize_or("shards", 1)?.max(1);
    let executors = args.usize_or("executors", 1)?.max(1);
    let pipeline_depth = args.usize_or("pipeline-depth", 2)?.max(1);
    // Workload knobs for the concurrent-load phase: guided rows double
    // the eval row mass per request; churn exercises stochastic ERA.
    let load_task = TaskSpec {
        guidance_scale: args.f64_or("guidance", 0.0)?,
        guide_class: args.usize_or("guide-class", 0)?,
        churn: args.f64_or("churn", 0.0)?,
        ..Default::default()
    };
    // QoS knobs for the load phase: non-strict classes opt requests
    // into the convergence controller and degraded admission.
    let qos_name = args.str_or("qos", "strict");
    let qos = QosClass::parse(&qos_name)
        .ok_or_else(|| format!("unknown qos class '{qos_name}'"))?;
    let min_nfe = args.usize_or("min-nfe", 0)?;
    let conv_threshold = args.f64_or("conv-threshold", 0.0)?;

    // ---- Part 1: Tab. 7 — single-request wall clock per solver × NFE ----
    let stack =
        start_stack(&artifacts, &dataset, BatchPolicy::default(), shards, executors, pipeline_depth)?;
    let addr = stack.server.local_addr();
    let mut client = Client::connect(addr).map_err(|e| e.to_string())?;
    client.ping()?;

    let solvers = ["pndm", "dpm-fast", "era-4@0.3"];
    let nfes = [15usize, 25, 50];
    let mut rows = Vec::new();
    for s in solvers {
        let mut row = vec![s.to_string()];
        for &nfe in &nfes {
            // Tab. 7 cells stay strict/fixed-NFE: the table measures
            // full-budget wall clock, not adaptive savings.
            let spec = RequestSpec {
                dataset: dataset.clone(),
                solver: s.into(),
                nfe,
                n_samples: batch,
                grid: "uniform".into(),
                t_end: 1e-4,
                seed: 11,
                deadline_ms: None,
                task: TaskSpec::default(),
                ..Default::default()
            };
            // Median of 5 runs.
            let mut times = Vec::new();
            for _ in 0..5 {
                let t0 = std::time::Instant::now();
                let _ = client.sample(&spec)?;
                times.push(t0.elapsed().as_secs_f64());
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            row.push(format!("{:.3}", times[times.len() / 2]));
            eprintln!("tab7 {s} nfe={nfe}: {:.3}s", times[times.len() / 2]);
        }
        rows.push(row);
    }
    let mut header = vec!["Sampling method \\ NFE (s/request)".to_string()];
    header.extend(nfes.iter().map(|n| n.to_string()));
    let t7 = Table {
        title: format!("Tab. 7 (serving wall-clock, dataset={dataset}, batch={batch})"),
        header,
        rows,
        footnote: "median of 5, single client, full TCP->coordinator->PJRT path".into(),
    };
    write_markdown_table(&out, &t7).map_err(|e| e.to_string())?;

    // ---- Part 2: concurrent load — throughput/latency ----
    let spec = RequestSpec {
        dataset: dataset.clone(),
        solver: "era-4@0.3".into(),
        nfe: 15,
        n_samples: batch,
        grid: "uniform".into(),
        t_end: 1e-4,
        seed: 0,
        deadline_ms: None,
        task: load_task,
        qos,
        min_nfe,
        conv_threshold,
        ..Default::default()
    };
    let report = generate_load_with(
        addr,
        &spec,
        &LoadOptions { concurrency: connections, requests_per_worker: requests, reuse, encoding },
    );
    println!(
        "\nload ({} conns, reuse={}, encoding={}): {} requests ({} errors) in {:.2}s -> \
         {:.0} samples/s, p50 {:.0}ms p99 {:.0}ms",
        connections,
        reuse,
        encoding.label(),
        report.requests,
        report.errors,
        report.wall_seconds,
        report.throughput_rows,
        1e3 * report.percentile(0.5),
        1e3 * report.percentile(0.99),
    );
    println!("pool: {}", stack.pool.stats().summary());
    let fused = stack.pool.stats().occupancy();
    if args.present("emit-bench-json") {
        use era_solver::obs::{BenchReport, Direction};
        let mut r = BenchReport::new("serving");
        r.push("throughput_rows_per_s", report.throughput_rows, Direction::HigherIsBetter, 0.5);
        r.push("p50_latency_s", report.percentile(0.5), Direction::LowerIsBetter, 1.0);
        r.push("p99_latency_s", report.percentile(0.99), Direction::LowerIsBetter, 1.0);
        r.push("errors", report.errors as f64, Direction::LowerIsBetter, 0.0);
        r.push("batch_occupancy_rows", fused, Direction::HigherIsBetter, 0.5);
        let path = args.str_or("emit-bench-json", "BENCH_serving.json");
        r.write_to(std::path::Path::new(&path)).map_err(|e| e.to_string())?;
        eprintln!("wrote bench report {path}");
    }
    stack.server.shutdown();

    // ---- Part 3: batching ablation — linger on vs off ----
    let mut lines = vec![format!(
        "| policy | samples/s | p50 ms | p99 ms | occupancy |\n|---|---|---|---|---|"
    )];
    for (name, policy) in [
        ("no-linger (min_rows=1)", BatchPolicy {
            max_rows: 256,
            min_rows: 1,
            max_wait: std::time::Duration::from_millis(0),
        }),
        ("linger (min_rows=128, 5ms)", BatchPolicy {
            max_rows: 256,
            min_rows: 128,
            max_wait: std::time::Duration::from_millis(5),
        }),
    ] {
        let stack = start_stack(&artifacts, &dataset, policy, shards, executors, pipeline_depth)?;
        let report = generate_load(stack.server.local_addr(), &spec, concurrency, requests);
        let occ = stack.pool.stats().occupancy();
        lines.push(format!(
            "| {name} | {:.0} | {:.0} | {:.0} | {:.1} |",
            report.throughput_rows,
            1e3 * report.percentile(0.5),
            1e3 * report.percentile(0.99),
            occ
        ));
        stack.server.shutdown();
    }
    let ablation = lines.join("\n");
    println!("\nbatching policy ablation (concurrency={concurrency}):\n{ablation}");
    let abl_path = out.replace(".md", "_policy.md");
    std::fs::write(&abl_path, format!("{ablation}\n")).map_err(|e| e.to_string())?;
    eprintln!("wrote {out} and {abl_path} (load occupancy {fused:.1})");
    Ok(())
}
